//! Benchmark harness (`cargo bench`): regenerates every table and figure
//! of the paper's evaluation section plus the §Perf micro-benchmarks.
//!
//! No criterion in the offline vendor set — this is a hand-rolled harness
//! (`harness = false`). Filter sections with
//! `cargo bench -- table1 fig10 perf` (no args = all sections).
//!
//! | section | paper artifact |
//! |---------|----------------|
//! | table1  | Table 1 — accuracy, GXNOR vs BNN/BWN/TWN/fp               |
//! | table2  | Table 2 — op counts + resting probability                 |
//! | fig7    | Fig. 7 — training curves, GXNOR vs full-precision         |
//! | fig8    | Fig. 8 — nonlinear factor m                               |
//! | fig9    | Fig. 9 — derivative pulse width a                         |
//! | fig10   | Fig. 10 — activation sparsity vs accuracy                 |
//! | fig13   | Fig. 13 — (N1, N2) discrete-space grid                    |
//! | perf    | §Perf — DST throughput, packing, exec latency, data rate  |
//! | kernels | bitplane lane micro-benches → BENCH_kernels.json          |
//! | serve   | open-loop serving latency bench → BENCH_serve.json        |
//!
//! The `kernels` section is the perf-regression harness: fixed
//! invocation/iteration counts with a warmup discard, a 1/4/8 lane-width
//! sweep of every hot bitplane kernel, and a compare mode —
//! `cargo bench -- kernels --baseline <BENCH_kernels.json> [--threshold 0.10]`
//! — that diffs per-kernel ns/iter against a previous run and exits
//! nonzero when any kernel regresses past the threshold.
//!
//! Budgets are sized for ~minutes, not paper-scale epochs: the claims
//! checked are *orderings and shapes*, recorded in EXPERIMENTS.md.

use std::time::Instant;

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{
    evaluate_engine, run_training, TrainBackend, TrainConfig, Trainer,
};
use gxnor::data::Dataset;
use gxnor::engine::backward;
use gxnor::engine::bitplane::{
    self, BitplaneCols, GateStats, KernelStrategy, PackScratch, PlaneSpec,
};
use gxnor::engine::NativeEngine;
use gxnor::hwsim::report::{fig12_example, table2};
use gxnor::metrics::Recorder;
use gxnor::runtime::client::{Arg, Runtime};
use gxnor::runtime::exec::ExecEngine as _;
use gxnor::runtime::manifest::Manifest;
use gxnor::sweep;
use gxnor::ternary::{dst_update, DiscreteSpace, PackedTensor};
use gxnor::util::json::{self, Json};
use gxnor::util::prng::Prng;
use gxnor::util::timer::{percentile, time_iters};

fn main() -> anyhow::Result<()> {
    // explicit arg walk: `--baseline <json>` / `--threshold <frac>` consume
    // a value (and accept `--flag=value`); any other `--flag` (cargo passes
    // some through) is ignored; bare words are section filters. A plain
    // `filter(|a| !a.starts_with("--"))` would misread a baseline path as a
    // section filter, so the loop owns the cursor.
    let mut filters: Vec<String> = Vec::new();
    let mut baseline: Option<String> = None;
    let mut threshold = 0.10f64;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        if let Some(v) = a.strip_prefix("--baseline=") {
            baseline = Some(v.to_string());
        } else if a == "--baseline" {
            baseline = Some(
                argv.next()
                    .ok_or_else(|| anyhow::anyhow!("--baseline needs a BENCH_kernels.json path"))?,
            );
        } else if let Some(v) = a.strip_prefix("--threshold=") {
            threshold = v.parse().map_err(|e| anyhow::anyhow!("--threshold: {e}"))?;
        } else if a == "--threshold" {
            let v = argv
                .next()
                .ok_or_else(|| anyhow::anyhow!("--threshold needs a fraction, e.g. 0.10"))?;
            threshold = v.parse().map_err(|e| anyhow::anyhow!("--threshold: {e}"))?;
        } else if !a.starts_with("--") {
            filters.push(a);
        }
    }
    let want = |name: &str| filters.is_empty() || filters.iter().any(|f| f == name);

    // artifacts and a PJRT backend gate the XLA-graph sections; the
    // native engine's sections (and the micro benches) run everywhere,
    // so `cargo bench -- perf` is useful even on a stub build.
    let manifest = Manifest::load("artifacts").ok();
    let mut rt = Runtime::new().ok();
    println!(
        "gxnor bench harness — platform {}\n",
        rt.as_ref().map(|r| r.platform()).unwrap_or_else(|| "none (xla stub)".into())
    );

    let graph_sections: &[(&str, SectionFn)] = &[
        ("table1", bench_table1 as SectionFn),
        ("table2", bench_table2),
        ("fig7", bench_fig7),
        ("fig8", |rt, m| bench_sweep(rt, m, "fig8", "m", &[0.5, 1.0, 2.0, 3.0, 5.0, 10.0])),
        ("fig9", |rt, m| bench_sweep(rt, m, "fig9", "a", &[0.1, 0.25, 0.5, 1.0, 2.0])),
        ("fig10", |rt, m| {
            bench_sweep(rt, m, "fig10", "r", &[0.05, 0.2, 0.35, 0.5, 0.65, 0.8, 0.95])
        }),
        ("fig13", bench_fig13),
        ("fig4", bench_fig4),
    ];
    for (name, f) in graph_sections {
        if !want(name) {
            continue;
        }
        match (rt.as_mut(), manifest.as_ref()) {
            (Some(rt), Some(m)) => f(rt, m)?,
            _ => println!("skipping {name}: needs artifacts + a PJRT backend\n"),
        }
    }
    if want("kernels") {
        bench_kernels(baseline.as_deref(), threshold)?;
    }
    if want("perf") {
        bench_perf(rt.as_mut(), manifest.as_ref())?;
    }
    if want("serve") {
        bench_serve()?;
    }
    Ok(())
}

type SectionFn = fn(&mut Runtime, &Manifest) -> anyhow::Result<()>;

fn base_cfg() -> TrainConfig {
    TrainConfig {
        train_len: 3000,
        test_len: 800,
        epochs: 3,
        verbose: false,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Table 1: method comparison on three datasets
// ---------------------------------------------------------------------------

fn bench_table1(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<()> {
    println!("== table1: accuracy by method (paper Table 1) ==");
    println!("(MLP on procedural datasets, 3 epochs — orderings, not absolutes)\n");
    let methods = [Method::Bnn, Method::Twn, Method::Bwn, Method::Fp, Method::Gxnor];
    let datasets = ["synth_mnist"];
    println!("{:<22} {:>14}", "Method", "synth_mnist");
    let mut rows = Vec::new();
    for method in methods {
        let mut row = format!("{:<22}", method.name());
        for ds in datasets {
            let mut cfg = TrainConfig {
                method,
                dataset: ds.into(),
                ..base_cfg()
            };
            if method == Method::Fp {
                // dense Adam wants a cooler LR than stochastic DST rounding
                cfg.lr_start = 5e-3;
                cfg.lr_fin = 5e-4;
            }
            let t0 = Instant::now();
            let rep = run_training(rt, manifest, cfg)?;
            row.push_str(&format!(
                " {:>12.2}% ({:.0}s)",
                100.0 * rep.test_acc,
                t0.elapsed().as_secs_f64()
            ));
            rows.push((method, rep.test_acc));
        }
        println!("{row}");
    }
    // shape check: GXNOR within reach of fp, above chance by a wide margin
    let acc = |m: Method| rows.iter().find(|(mm, _)| *mm == m).unwrap().1;
    println!(
        "\nshape: gxnor {:.1}% vs fp {:.1}% (paper: comparable); all methods >> 10% chance",
        100.0 * acc(Method::Gxnor),
        100.0 * acc(Method::Fp)
    );
    println!();
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 + Fig. 12
// ---------------------------------------------------------------------------

fn bench_table2(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<()> {
    println!("== table2: operation overheads (paper Table 2) ==\n");
    print!("{}", table2(100, 1.0 / 3.0, 1.0 / 3.0));
    let (nominal, mean) = fig12_example(20_000, 7);
    println!("\nfig12: {nominal} nominal XNOR -> {mean:.2} active (paper: 21 -> 9)\n");

    // measured-mode row from a quick training run
    let cfg = TrainConfig { epochs: 2, train_len: 2000, test_len: 400, ..base_cfg() };
    let rep = run_training(rt, manifest, cfg)?;
    println!(
        "measured state distributions: weight p0 = {:.3}, act p0 = {:.3}",
        rep.weight_zero_fraction, rep.mean_act_sparsity
    );
    print!(
        "{}",
        table2(100, rep.weight_zero_fraction, rep.mean_act_sparsity)
    );
    println!();
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7: training curves gxnor vs fp
// ---------------------------------------------------------------------------

fn bench_fig7(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<()> {
    println!("== fig7: error vs epoch, GXNOR vs full-precision (paper Fig. 7) ==\n");
    let mut curves: Vec<(String, Recorder, f64)> = Vec::new();
    for method in [Method::Gxnor, Method::Fp] {
        let mut cfg = TrainConfig {
            method,
            epochs: 6,
            train_len: 4000,
            test_len: 800,
            ..base_cfg()
        };
        if method == Method::Fp {
            cfg.lr_start = 5e-3;
            cfg.lr_fin = 5e-4;
        }
        let rep = run_training(rt, manifest, cfg)?;
        curves.push((method.name(), rep.recorder, rep.test_acc));
    }
    for (name, rec, acc) in &curves {
        let errs: Vec<String> = rec
            .get("test_err")
            .iter()
            .map(|e| format!("{:.1}%", 100.0 * e))
            .collect();
        println!(
            "{:<8} final {:>6.2}%  err/epoch: {}  {}",
            name,
            100.0 * acc,
            errs.join(" "),
            rec.sparkline("test_err", 24)
        );
    }
    let (g, f) = (curves[0].2, curves[1].2);
    println!(
        "\nshape: fp converges faster, gxnor comparable at the end \
         (gxnor {:.1}% vs fp {:.1}%)\n",
        100.0 * g,
        100.0 * f
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 8/9/10: scalar sweeps
// ---------------------------------------------------------------------------

fn bench_sweep(
    rt: &mut Runtime,
    manifest: &Manifest,
    fig: &str,
    param: &str,
    values: &[f64],
) -> anyhow::Result<()> {
    println!("== {fig}: sweep of {param} (paper Fig. {}) ==\n", &fig[3..]);
    let base = base_cfg();
    let mut backend = TrainBackend::Xla { rt, manifest };
    let points = sweep::sweep_scalar(&mut backend, &base, param, values)?;
    print!("{}", sweep::render_table(&format!("{fig}: {param}"), &points));
    if let Some(b) = sweep::best(&points) {
        let interior = b
            .value
            .is_some_and(|v| v > values[0] && v < values[values.len() - 1]);
        println!(
            "best: {} ({:.2}%) — {}\n",
            b.label,
            100.0 * b.test_acc,
            if interior {
                "interior optimum, matching the paper's U-shape"
            } else {
                "edge optimum on this budget (paper reports an interior one)"
            }
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 13: (N1, N2) grid
// ---------------------------------------------------------------------------

fn bench_fig13(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<()> {
    println!("== fig13: discrete-space grid (paper Fig. 13) ==\n");
    let base = base_cfg();
    let grid: Vec<(u32, u32)> = vec![(0, 0), (1, 1), (2, 2), (3, 3), (4, 4), (6, 4)];
    let mut backend = TrainBackend::Xla { rt, manifest };
    let points = sweep::sweep_levels(&mut backend, &base, &grid)?;
    print!("{}", sweep::render_table("fig13: N1,N2", &points));
    if let Some(b) = sweep::best(&points) {
        println!(
            "best: {} — finer spaces beat binary/ternary up to an interior optimum \
             (paper: N1=6, N2=4)\n",
            b.label
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 ablation: DST vs hidden-weight training
// ---------------------------------------------------------------------------

fn bench_fig4(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<()> {
    use gxnor::coordinator::trainer::UpdateRule;
    println!("== fig4: DST (paper) vs hidden-weight baseline (Fig. 4a) ==\n");
    println!(
        "{:<22} {:>10} {:>16} {:>14}",
        "update rule", "test_acc", "weight mem (B)", "fp32 masters"
    );
    for (rule, label) in [
        (UpdateRule::Dst, "dst (no fp copy)"),
        (UpdateRule::Hidden, "hidden (fp masters)"),
    ] {
        let cfg = TrainConfig {
            method: Method::Gxnor,
            update_rule: rule,
            epochs: 4,
            train_len: 4000,
            test_len: 800,
            ..base_cfg()
        };
        let rep = run_training(rt, manifest, cfg)?;
        println!(
            "{:<22} {:>9.2}% {:>16} {:>14}",
            label,
            100.0 * rep.test_acc,
            rep.packed_bytes + rep.hidden_fp32_bytes,
            rep.hidden_fp32_bytes
        );
    }
    println!(
        "\nshape: comparable accuracy; DST removes the O(#weights) fp copy \
         entirely (the paper's Remark 2)\n"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// §Perf micro-benchmarks
// ---------------------------------------------------------------------------

fn bench_perf(mut rt: Option<&mut Runtime>, manifest: Option<&Manifest>) -> anyhow::Result<()> {
    println!("== perf: hot-path micro-benchmarks (EXPERIMENTS.md §Perf) ==\n");

    // DST update throughput (the L3 hot path)
    let space = DiscreteSpace::TERNARY;
    let n = 1_000_000;
    let mut rng = Prng::new(1);
    let mut w: Vec<f32> = (0..n).map(|_| space.state(rng.below(3))).collect();
    let dw: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
    let (mean_ms, min_ms, _) = time_iters(20, || {
        dst_update(&mut w, &dw, space, 3.0, &mut rng, 1);
    });
    println!(
        "dst_update       : {:>8.2} ms / 1M weights  ({:.0} Mupd/s, min {:.2} ms)",
        mean_ms,
        n as f64 / mean_ms / 1e3,
        min_ms
    );

    // pack/unpack throughput (PJRT boundary cost)
    let packed = PackedTensor::pack(&w, &[n], space);
    let mut buf = vec![0.0f32; n];
    let (unpack_ms, _, _) = time_iters(20, || packed.unpack_into(&mut buf));
    let mut packed2 = packed.clone();
    let (repack_ms, _, _) = time_iters(20, || packed2.repack_from(&buf));
    println!(
        "unpack_into      : {:>8.2} ms / 1M weights  ({:.1} GB/s f32-out)",
        unpack_ms,
        4.0 * n as f64 / unpack_ms / 1e6
    );
    println!("repack_from      : {:>8.2} ms / 1M weights", repack_ms);

    // PRNG throughput
    let mut acc = 0u64;
    let (prng_ms, _, _) = time_iters(10, || {
        for _ in 0..1_000_000 {
            acc ^= rng.next_u64();
        }
    });
    std::hint::black_box(acc);
    println!(
        "prng             : {:>8.2} ms / 1M draws     ({:.0} Mdraw/s)",
        prng_ms,
        1e3 / prng_ms
    );

    // dataset generation rate
    let ds = gxnor::data::SynthDigits::new(1, 10_000);
    let mut x = vec![0.0f32; ds.sample_len()];
    let (gen_ms, _, _) = time_iters(3, || {
        for i in 0..1000 {
            ds.fill(i, &mut x);
        }
    });
    println!(
        "synth_mnist gen  : {:>8.2} ms / 1k samples   ({:.0} samples/s)",
        gen_ms,
        1e6 / gen_ms
    );

    // graph execution latency: train + infer steps, b100 MLP and CNN
    // (needs artifacts + a PJRT backend; skipped silently otherwise)
    if let (Some(rt), Some(manifest)) = (rt.as_deref_mut(), manifest) {
        for gname in ["mlp_multi_b100_train", "cnn_mnist_multi_b100_train"] {
            let g = match manifest.get(gname) {
                Ok(g) => g.clone(),
                Err(_) => continue,
            };
            rt.load(&g)?;
            let x = vec![0.1f32; g.batch * g.sample_len()];
            let labels = vec![0i32; g.batch];
            let params: Vec<Vec<f32>> =
                g.params.iter().map(|p| vec![0.0f32; p.numel()]).collect();
            let bns: Vec<Vec<f32>> = g
                .bn_state
                .iter()
                .map(|s| {
                    if s.name.starts_with("rvar") {
                        vec![1.0; s.numel()]
                    } else {
                        vec![0.0; s.numel()]
                    }
                })
                .collect();
            let mut args: Vec<Arg> = vec![
                Arg::F32(&x),
                Arg::I32(&labels),
                Arg::Scalar(0.5),
                Arg::Scalar(0.5),
                Arg::Scalar(1.0),
            ];
            for p in &params {
                args.push(Arg::F32(p));
            }
            for s in &bns {
                args.push(Arg::F32(s));
            }
            // warmup
            rt.execute(&g, &args)?;
            let (exec_ms, min_ms, _) = time_iters(10, || {
                rt.execute(&g, &args).unwrap();
            });
            println!(
                "{:<17}: {:>8.1} ms / step (min {:.1} ms, batch {})",
                gname, exec_ms, min_ms, g.batch
            );
        }
    }
    println!();
    let xla_step = match (rt.as_deref_mut(), manifest) {
        (Some(rt), Some(m)) => Some(bench_step_loop(rt, m)?),
        _ => {
            println!("(xla step A/B skipped: needs artifacts + a PJRT backend)\n");
            None
        }
    };
    let native_step = bench_native_step()?;
    write_bench_step(xla_step, &native_step)?;
    if let (Some(rt), Some(m)) = (rt.as_deref_mut(), manifest) {
        bench_infer(rt, m)?;
    } else {
        println!("(inference A/B skipped: needs artifacts + a PJRT backend)\n");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// serve: open-loop serving latency benchmark (BENCH_serve.json)
// ---------------------------------------------------------------------------

/// The `serve` bench section: an in-process `gxnor serve --bench` run at a
/// modest sustained rate — fresh-init model (latency only, no accuracy
/// claim), replica-per-core, Poisson open-loop arrivals with the warmup
/// discarded on both the client and server side. Writes the
/// `bench_serve.v1` document to `BENCH_serve.json`.
fn bench_serve() -> anyhow::Result<()> {
    use gxnor::serve::{run_bench, EngineSpec, LoadgenCfg, ServeConfig};
    println!("== serve: open-loop serving latency (BENCH_serve.json) ==\n");
    let spec = EngineSpec {
        arch: "mlp".into(),
        method: Method::Gxnor,
        r: 0.5,
        ckpt: None,
        artifacts: "artifacts".into(),
        seed: 42,
    };
    let serve_cfg = ServeConfig {
        replicas: 0, // one per core
        max_batch: 32,
        max_wait_ms: 2.0,
        queue_bound: 256,
        deadline_ms: 0.0,
    };
    let load_cfg = LoadgenCfg {
        rps: 300.0,
        duration_s: 2.5,
        warmup_s: 0.5,
        conns: 16,
        seed: 42,
        sample_len: 0, // filled from the engine by run_bench
        deadline_ms: 0,
    };
    let doc = run_bench(&spec, &serve_cfg, &load_cfg, 1)?;
    let g = |path: &[&str]| {
        let mut j = &doc;
        for &k in path {
            j = j.get(k)?;
        }
        j.as_f64()
    };
    println!(
        "offered {:.0} rps -> completed {:.0} ({:.0} rps), shed {:.0}, \
         p50 {:.2} ms, p99 {:.2} ms, mean batch fill {:.2}",
        g(&["config", "rps"]).unwrap_or(0.0),
        g(&["load", "completed"]).unwrap_or(0.0),
        g(&["load", "throughput_rps"]).unwrap_or(0.0),
        g(&["load", "shed"]).unwrap_or(0.0),
        g(&["load", "latency_ms", "p50_ms"]).unwrap_or(0.0),
        g(&["load", "latency_ms", "p99_ms"]).unwrap_or(0.0),
        g(&["server", "mean_batch_fill"]).unwrap_or(0.0),
    );
    let text = doc.to_string();
    std::fs::write("BENCH_serve.json", &text)?;
    if std::path::Path::new("../ROADMAP.md").exists() {
        std::fs::write("../BENCH_serve.json", &text)?;
    }
    println!("wrote BENCH_serve.json (schema bench_serve.v1)\n");
    Ok(())
}

// ---------------------------------------------------------------------------
// §Perf inference A/B: XLA graph vs native gated-XNOR (BENCH_infer.json)
// ---------------------------------------------------------------------------

/// Evaluate the same trained model through both `ExecEngine` backends,
/// record packed-domain samples/sec for each plus the native engine's
/// measured gate rates, sweep the native engine's thread count (1/2/4),
/// A/B the packed im2col conv against the scalar oracle, and write
/// `BENCH_infer.json` (schema `bench_infer.v2`, documented in README).
fn bench_infer(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<()> {
    println!("== perf: inference engine A/B (BENCH_infer.json) ==\n");
    let cfg = TrainConfig { epochs: 1, train_len: 2000, test_len: 1000, ..base_cfg() };
    let train =
        gxnor::data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    let test =
        gxnor::data::open(&cfg.dataset, false, cfg.test_len).map_err(anyhow::Error::msg)?;
    let mut tr = Trainer::new(rt, manifest, cfg)?;
    tr.run(train.as_ref(), test.as_ref())?; // trained weights + BN state

    // native engine: warm pass, then a timed pass on fresh gate counters
    let mut nat = tr.native_engine()?;
    let batch = nat.batch();
    evaluate_engine(&mut nat, test.as_ref())?;
    nat.reset_gate_stats();
    let t0 = Instant::now();
    let acc_native = evaluate_engine(&mut nat, test.as_ref())?;
    let native_secs = t0.elapsed().as_secs_f64();
    let gate = nat.total_gate_stats();
    let per_layer = nat.gate_report();
    let nat_threads = nat.threads();

    // XLA engine view over the exact same model state
    let graph = tr.infer_graph_name().to_string();
    let (acc_xla, xla_secs) = {
        let mut xla = tr.xla_engine()?;
        evaluate_engine(&mut xla, test.as_ref())?; // warm
        let t0 = Instant::now();
        let acc = evaluate_engine(&mut xla, test.as_ref())?;
        (acc, t0.elapsed().as_secs_f64())
    };

    let n = test.len() as f64;
    // padded rows execute too: normalize gate counts by evaluated rows
    let rows = (test.len().div_ceil(batch) * batch) as f64;
    println!(
        "xla engine       : {:>8.0} samples/s  acc {:.2}%",
        n / xla_secs.max(1e-12),
        100.0 * acc_xla
    );
    println!(
        "native engine    : {:>8.0} samples/s  acc {:.2}%  gated XNOR {:.0}/sample \
         ({:.1}% of nominal resting)",
        n / native_secs.max(1e-12),
        100.0 * acc_native,
        gate.xnor as f64 / rows,
        100.0 * gate.resting_rate()
    );
    for r in &per_layer {
        println!(
            "  {:<24} resting {:>5.1}%  (w0 {:.3}, x0 {:.3})",
            r.name,
            100.0 * r.stats.resting_rate(),
            r.w_zero_fraction,
            r.stats.x_zero_fraction()
        );
    }

    // thread-scaling sweep on the same engine + model: samples/sec at
    // 1/2/4 workers, with the merged GateStats pinned identical across
    // counts (the determinism guarantee, measured not assumed)
    println!("\nthread scaling (native engine):");
    let mut scaling: Vec<(usize, f64, f64)> = Vec::new();
    let mut stats_match = true;
    let mut ref_stats: Option<GateStats> = None;
    for threads in [1usize, 2, 4] {
        nat.set_threads(threads);
        nat.reset_gate_stats();
        let t0 = Instant::now();
        let acc = evaluate_engine(&mut nat, test.as_ref())?;
        let secs = t0.elapsed().as_secs_f64();
        let total = nat.total_gate_stats();
        if let Some(r) = ref_stats {
            if r != total {
                stats_match = false;
            }
        } else {
            ref_stats = Some(total);
        }
        let sps = n / secs.max(1e-12);
        println!("  threads {threads}: {:>8.0} samples/s  acc {:.2}%", sps, 100.0 * acc);
        scaling.push((threads, sps, acc));
    }
    let speedup4 = scaling[2].1 / scaling[0].1.max(1e-12);
    println!(
        "  4-thread speedup {speedup4:.2}x over 1 thread; merged GateStats identical: {stats_match}"
    );

    // packed-domain im2col conv vs the per-pixel scalar oracle, on a
    // full-width cnn_mnist built straight from an initialized model (no
    // artifacts needed for this half)
    println!("\nconv lowering A/B (cnn_mnist, 200 samples):");
    let conv_ab = bench_conv_ab(200)?;
    for (name, im2col_sps, scalar_sps) in &conv_ab {
        println!(
            "  {name:<6} im2col {:>7.1} samples/s  vs scalar {:>7.1}  ({:.2}x)",
            im2col_sps,
            scalar_sps,
            im2col_sps / scalar_sps.max(1e-12)
        );
    }

    let eng_fields = |sps: f64, acc: f64| {
        vec![
            ("samples_per_sec".to_string(), Json::Num(sps)),
            ("accuracy".to_string(), Json::Num(acc)),
        ]
    };
    let mut native_obj = eng_fields(n / native_secs.max(1e-12), acc_native);
    native_obj.push(("threads".into(), Json::Num(nat_threads as f64)));
    native_obj.push(("gated_xnor_per_sample".into(), Json::Num(gate.xnor as f64 / rows)));
    native_obj.push(("nominal_ops_per_sample".into(), Json::Num(gate.total as f64 / rows)));
    native_obj.push(("resting_fraction".into(), Json::Num(gate.resting_rate())));
    let doc = Json::Obj(vec![
        ("schema".into(), Json::Str("bench_infer.v3".into())),
        ("provenance".into(), json::provenance(gxnor::engine::bitplane::LANE_WORDS)),
        ("graph".into(), Json::Str(graph)),
        ("batch".into(), Json::Num(batch as f64)),
        ("samples".into(), Json::Num(n)),
        ("xla".into(), Json::Obj(eng_fields(n / xla_secs.max(1e-12), acc_xla))),
        ("native".into(), Json::Obj(native_obj)),
        (
            "thread_scaling".into(),
            Json::Arr(
                scaling
                    .iter()
                    .map(|&(t, sps, acc)| {
                        Json::Obj(vec![
                            ("threads".into(), Json::Num(t as f64)),
                            ("samples_per_sec".into(), Json::Num(sps)),
                            ("accuracy".into(), Json::Num(acc)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_4_threads".into(), Json::Num(speedup4)),
        ("gate_stats_match_across_threads".into(), Json::Bool(stats_match)),
        (
            "conv_ab".into(),
            Json::Arr(
                conv_ab
                    .iter()
                    .map(|(name, im2col_sps, scalar_sps)| {
                        Json::Obj(vec![
                            ("method".into(), Json::Str(name.clone())),
                            ("im2col_samples_per_sec".into(), Json::Num(*im2col_sps)),
                            ("scalar_samples_per_sec".into(), Json::Num(*scalar_sps)),
                            (
                                "speedup".into(),
                                Json::Num(im2col_sps / scalar_sps.max(1e-12)),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "per_layer_gate".into(),
            Json::Arr(
                per_layer
                    .iter()
                    .map(|r| {
                        Json::Obj(vec![
                            ("name".into(), Json::Str(r.name.clone())),
                            ("resting_rate".into(), Json::Num(r.stats.resting_rate())),
                            ("w_zero".into(), Json::Num(r.w_zero_fraction)),
                            ("x_zero".into(), Json::Num(r.stats.x_zero_fraction())),
                            // v3: measured occupancy, the kernel the adaptive
                            // dispatch picks for it, and the per-row histogram
                            // (bins: <=0.02, <=0.1, <=0.5, <=0.9, >0.9)
                            (
                                "occupancy".into(),
                                Json::Num(1.0 - r.stats.x_zero_fraction()),
                            ),
                            ("strategy".into(), Json::Str(r.strategy.name().into())),
                            (
                                "occupancy_histogram".into(),
                                Json::Arr(
                                    r.stats
                                        .occ_hist
                                        .iter()
                                        .map(|&c| Json::Num(c as f64))
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("accuracy_match".into(), Json::Bool(acc_xla == acc_native)),
    ]);
    let text = doc.to_string();
    std::fs::write("BENCH_infer.json", &text)?;
    if std::path::Path::new("../ROADMAP.md").exists() {
        std::fs::write("../BENCH_infer.json", &text)?;
    }
    println!("\nwrote BENCH_infer.json (accuracy match: {})\n", acc_xla == acc_native);
    Ok(())
}

/// Packed-domain im2col conv vs the per-pixel scalar oracle, per packed
/// method, on a full-width cnn_mnist (32C5-MP2-64C5-MP2-512FC-10) built
/// straight from an initialized model — no artifacts, no training; the
/// A/B isolates the conv lowering, so both engines run single-threaded.
/// Returns `(method, im2col samples/sec, scalar samples/sec)` rows.
fn bench_conv_ab(samples: usize) -> anyhow::Result<Vec<(String, f64, f64)>> {
    use gxnor::nn::init::init_model;
    use gxnor::nn::params::{ParamDesc, ParamKind};
    let ds = gxnor::data::open("synth_mnist", false, samples).map_err(anyhow::Error::msg)?;
    let d = |name: &str, shape: Vec<usize>, kind, layer| ParamDesc {
        name: name.into(),
        shape,
        kind,
        layer,
    };
    use ParamKind::*;
    let mut rows = Vec::new();
    for (method, space) in [
        (Method::Gxnor, DiscreteSpace::TERNARY),
        (Method::Bnn, DiscreteSpace::BINARY),
    ] {
        let model = init_model(
            vec![
                d("W0", vec![5, 5, 1, 32], Weight, 0),
                d("gamma0", vec![32], Gamma, 0),
                d("beta0", vec![32], Beta, 0),
                d("W1", vec![5, 5, 32, 64], Weight, 1),
                d("gamma1", vec![64], Gamma, 1),
                d("beta1", vec![64], Beta, 1),
                d("W2", vec![1024, 512], Weight, 2),
                d("gamma2", vec![512], Gamma, 2),
                d("beta2", vec![512], Beta, 2),
                d("W3", vec![512, 10], Weight, 3),
            ],
            ["rmean0", "rvar0", "rmean1", "rvar1", "rmean2", "rvar2"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            &[32, 32, 64, 64, 512, 512],
            space,
            77,
        );
        let timed = |eng: &mut NativeEngine| -> anyhow::Result<f64> {
            evaluate_engine(eng, ds.as_ref())?; // warm (allocations, caches)
            let t0 = Instant::now();
            evaluate_engine(eng, ds.as_ref())?;
            Ok(samples as f64 / t0.elapsed().as_secs_f64().max(1e-12))
        };
        let mut im2col =
            NativeEngine::from_model("cnn_mnist", method, &model, 0.5, 50, 10, 1)?;
        let mut scalar =
            NativeEngine::from_model("cnn_mnist", method, &model, 0.5, 50, 10, 1)?;
        // conv-only scalarization: dense layers stay packed in both arms,
        // so the measured delta is the conv lowering and nothing else
        scalar.force_scalar_conv();
        let im2col_sps = timed(&mut im2col)?;
        let scalar_sps = timed(&mut scalar)?;
        rows.push((method.name(), im2col_sps, scalar_sps));
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// §Perf step-loop A/B: legacy one-shot boundary vs pooled zero-copy boundary
// ---------------------------------------------------------------------------

/// Per-variant timing of the full training step (exec + update + marshal).
struct StepTiming {
    graph: String,
    steps_per_sec: f64,
    step_ms_mean: f64,
    exec_ms: f64,
    update_ms: f64,
    marshal_ms: f64,
    p50_ms: f64,
    p99_ms: f64,
}

impl StepTiming {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("steps_per_sec".into(), Json::Num(self.steps_per_sec)),
            ("step_ms_mean".into(), Json::Num(self.step_ms_mean)),
            ("exec_ms".into(), Json::Num(self.exec_ms)),
            ("update_ms".into(), Json::Num(self.update_ms)),
            ("marshal_ms".into(), Json::Num(self.marshal_ms)),
            ("p50_ms".into(), Json::Num(self.p50_ms)),
            ("p99_ms".into(), Json::Num(self.p99_ms)),
        ])
    }
}

/// Run `steps` full training steps on one fixed batch through either the
/// pooled (`Trainer::step`) or the legacy (`Trainer::step_unpooled`)
/// boundary, on a fresh trainer (compilation is cached in `rt`, so only
/// the first variant pays it — warmup absorbs the remainder).
fn measure_steps(
    rt: &mut Runtime,
    manifest: &Manifest,
    cfg: &TrainConfig,
    train: &dyn Dataset,
    pooled: bool,
    steps: usize,
) -> anyhow::Result<StepTiming> {
    let mut tr = Trainer::new(rt, manifest, cfg.clone())?;
    let b = tr.batch_size();
    let sl = train.sample_len();
    let mut x = vec![0.0f32; b * sl];
    let mut y = vec![0i32; b];
    for i in 0..b {
        y[i] = train.fill(i % train.len(), &mut x[i * sl..(i + 1) * sl]) as i32;
    }
    let lr = 1e-3;
    for _ in 0..3 {
        if pooled {
            tr.step(&x, &y, lr)?;
        } else {
            tr.step_unpooled(&x, &y, lr)?;
        }
    }
    // warmup paid compilation cache-fill, first-touch and (pooled) the
    // initial all-params refill — drop it from the per-phase means so
    // BENCH_step.json records the steady state only.
    tr.sw_exec.reset();
    tr.sw_update.reset();
    tr.sw_marshal.reset();
    let mut per_step = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for _ in 0..steps {
        let ts = Instant::now();
        if pooled {
            tr.step(&x, &y, lr)?;
        } else {
            tr.step_unpooled(&x, &y, lr)?;
        }
        per_step.push(ts.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(StepTiming {
        graph: tr.graph_name().to_string(),
        steps_per_sec: steps as f64 / wall.max(1e-12),
        step_ms_mean: 1e3 * wall / steps as f64,
        exec_ms: tr.sw_exec.mean_ms(),
        update_ms: tr.sw_update.mean_ms(),
        marshal_ms: tr.sw_marshal.mean_ms(),
        p50_ms: percentile(&per_step, 50.0),
        p99_ms: percentile(&per_step, 99.0),
    })
}

/// Steps/sec on the mlp train graph, legacy vs pooled boundary. Returns
/// the `xla` object of `BENCH_step.json` (schema v2); the caller merges
/// it with the native step bench and writes the file.
fn bench_step_loop(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<Json> {
    println!("== perf: step-loop boundary A/B (BENCH_step.json) ==\n");
    let cfg = TrainConfig { epochs: 1, train_len: 2000, test_len: 400, ..base_cfg() };
    let train =
        gxnor::data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    const STEPS: usize = 30;

    let baseline = measure_steps(rt, manifest, &cfg, train.as_ref(), false, STEPS)?;
    let pooled = measure_steps(rt, manifest, &cfg, train.as_ref(), true, STEPS)?;
    let speedup = pooled.steps_per_sec / baseline.steps_per_sec.max(1e-12);

    // end-to-end: pooled boundary + pipelined prefetch across a real epoch
    let run_rep = run_training(rt, manifest, cfg.clone())?;

    let graph_name = pooled.graph.clone();
    println!(
        "legacy boundary  : {:>8.2} steps/s  (step {:.1} ms, marshal {:.2} ms)",
        baseline.steps_per_sec, baseline.step_ms_mean, baseline.marshal_ms
    );
    println!(
        "pooled boundary  : {:>8.2} steps/s  (step {:.1} ms, marshal {:.2} ms, p50 {:.1}, p99 {:.1})",
        pooled.steps_per_sec, pooled.step_ms_mean, pooled.marshal_ms, pooled.p50_ms, pooled.p99_ms
    );
    println!(
        "pipelined run    : {:>8.2} steps/s  (prefetch on, incl. eval epochs)",
        run_rep.steps_per_sec
    );
    println!("speedup          : {speedup:.2}x (pooled vs legacy)\n");

    Ok(Json::Obj(vec![
        ("graph".into(), Json::Str(graph_name)),
        ("steps_measured".into(), Json::Num(STEPS as f64)),
        ("baseline".into(), baseline.to_json()),
        ("pooled".into(), pooled.to_json()),
        (
            "pipelined_run".into(),
            Json::Obj(vec![
                ("steps_per_sec".into(), Json::Num(run_rep.steps_per_sec)),
                ("step_p50_ms".into(), Json::Num(run_rep.step_p50_ms)),
                ("step_p99_ms".into(), Json::Num(run_rep.step_p99_ms)),
                ("exec_ms".into(), Json::Num(run_rep.exec_time_ms)),
                ("update_ms".into(), Json::Num(run_rep.dst_time_ms)),
                ("marshal_ms".into(), Json::Num(run_rep.marshal_time_ms)),
            ]),
        ),
        ("speedup_pooled_vs_baseline".into(), Json::Num(speedup)),
    ]))
}

// ---------------------------------------------------------------------------
// §Perf native training step: device-free DST step + thread-scaling sweep
// ---------------------------------------------------------------------------

/// One thread count's measurement of the native training step.
struct NativeStepPoint {
    threads: usize,
    steps_per_sec: f64,
    p50_ms: f64,
    exec_ms: f64,
    update_ms: f64,
}

/// Results of the native step bench (the `native` half of
/// `BENCH_step.json` v2).
struct NativeStepBench {
    arch: String,
    batch: usize,
    steps: usize,
    scaling: Vec<NativeStepPoint>,
    /// final model bytes identical across every thread count — the
    /// determinism guarantee measured, not assumed
    trajectory_identical: bool,
    packed_weight_bytes: usize,
    bitplane_bytes: usize,
    weight_f32_mirror_bytes: usize,
}

/// Run N native DST training steps on a fixed batch at 1/2/4 worker
/// threads (fresh trainer per count, same seed) and verify the final
/// model is bit-identical across the sweep. Fully device-free.
fn bench_native_step() -> anyhow::Result<NativeStepBench> {
    use gxnor::coordinator::trainer::NativeTrainer;
    println!("== perf: native DST training step (device-free) ==\n");
    const STEPS: usize = 20;
    let ds = gxnor::data::open("synth_mnist", true, 2000).map_err(anyhow::Error::msg)?;
    let mut scaling = Vec::new();
    let mut fingerprint: Option<Vec<u8>> = None;
    let mut identical = true;
    let mut mem = (0usize, 0usize, 0usize);
    let mut arch_batch = (String::new(), 0usize);
    for threads in [1usize, 2, 4] {
        let cfg = TrainConfig {
            method: Method::Gxnor,
            threads,
            verbose: false,
            ..Default::default()
        };
        let mut tr = NativeTrainer::new(None, cfg)?;
        let b = tr.batch_size();
        let sl = ds.sample_len();
        let mut x = vec![0.0f32; b * sl];
        let mut y = vec![0i32; b];
        for i in 0..b {
            y[i] = ds.fill(i % ds.len(), &mut x[i * sl..(i + 1) * sl]) as i32;
        }
        let lr = 1e-3;
        for _ in 0..3 {
            tr.step(&x, &y, b, lr)?; // warmup: first-touch + initial packs
        }
        tr.sw_exec.reset();
        tr.sw_update.reset();
        let mut per_step = Vec::with_capacity(STEPS);
        let t0 = Instant::now();
        for _ in 0..STEPS {
            let ts = Instant::now();
            tr.step(&x, &y, b, lr)?;
            per_step.push(ts.elapsed().as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        let point = NativeStepPoint {
            threads,
            steps_per_sec: STEPS as f64 / wall.max(1e-12),
            p50_ms: percentile(&per_step, 50.0),
            exec_ms: tr.sw_exec.mean_ms(),
            update_ms: tr.sw_update.mean_ms(),
        };
        println!(
            "  threads {threads}: {:>7.2} steps/s  p50 {:.1} ms  (fwd+bwd {:.1} ms, DST {:.2} ms)",
            point.steps_per_sec, point.p50_ms, point.exec_ms, point.update_ms
        );
        scaling.push(point);
        let fp = tr.model.fingerprint();
        if let Some(want) = &fingerprint {
            if *want != fp {
                identical = false;
            }
        } else {
            fingerprint = Some(fp);
        }
        let (packed, _) = tr.model.weight_memory_bytes();
        mem = (packed, tr.engine_bitplane_bytes(), 0);
        arch_batch = (tr.config().arch.clone(), b);
    }
    let s1 = scaling[0].steps_per_sec;
    let s4 = scaling[2].steps_per_sec;
    println!(
        "  4-thread speedup {:.2}x over 1 thread; trained model bit-identical across \
         threads: {identical}\n",
        s4 / s1.max(1e-12)
    );
    Ok(NativeStepBench {
        arch: arch_batch.0,
        batch: arch_batch.1,
        steps: STEPS,
        scaling,
        trajectory_identical: identical,
        packed_weight_bytes: mem.0,
        bitplane_bytes: mem.1,
        weight_f32_mirror_bytes: mem.2,
    })
}

/// Assemble and write `BENCH_step.json` schema v2: the XLA step A/B
/// (when a backend exists — `null` on stub builds) next to the native
/// training step's thread-scaling sweep, plus the cross-engine speedup.
fn write_bench_step(xla: Option<Json>, native: &NativeStepBench) -> anyhow::Result<()> {
    let xla_pooled_sps = xla.as_ref().and_then(|x| {
        x.get("pooled")
            .and_then(|p| p.get("steps_per_sec"))
            .and_then(Json::as_f64)
    });
    let native_best = native
        .scaling
        .iter()
        .map(|p| p.steps_per_sec)
        .fold(0.0f64, f64::max);
    let native_obj = Json::Obj(vec![
        ("arch".into(), Json::Str(native.arch.clone())),
        ("batch".into(), Json::Num(native.batch as f64)),
        ("steps_measured".into(), Json::Num(native.steps as f64)),
        (
            "thread_scaling".into(),
            Json::Arr(
                native
                    .scaling
                    .iter()
                    .map(|p| {
                        Json::Obj(vec![
                            ("threads".into(), Json::Num(p.threads as f64)),
                            ("steps_per_sec".into(), Json::Num(p.steps_per_sec)),
                            ("step_p50_ms".into(), Json::Num(p.p50_ms)),
                            ("exec_ms".into(), Json::Num(p.exec_ms)),
                            ("update_ms".into(), Json::Num(p.update_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "trajectory_bit_identical_across_threads".into(),
            Json::Bool(native.trajectory_identical),
        ),
        ("weight_f32_mirror_bytes".into(), Json::Num(native.weight_f32_mirror_bytes as f64)),
        ("packed_weight_bytes".into(), Json::Num(native.packed_weight_bytes as f64)),
        ("bitplane_bytes".into(), Json::Num(native.bitplane_bytes as f64)),
    ]);
    let doc = Json::Obj(vec![
        ("schema".into(), Json::Str("bench_step.v2".into())),
        ("provenance".into(), json::provenance(gxnor::engine::bitplane::LANE_WORDS)),
        ("xla".into(), xla.unwrap_or(Json::Null)),
        ("native".into(), native_obj),
        (
            "native_vs_xla_step_speedup".into(),
            match xla_pooled_sps {
                Some(x) if x > 0.0 => Json::Num(native_best / x),
                _ => Json::Null,
            },
        ),
    ]);
    let text = doc.to_string();
    std::fs::write("BENCH_step.json", &text)?;
    // also drop a copy at the repo root when benching from rust/
    if std::path::Path::new("../ROADMAP.md").exists() {
        std::fs::write("../BENCH_step.json", &text)?;
    }
    println!("wrote BENCH_step.json (schema v2)\n");
    Ok(())
}

// ---------------------------------------------------------------------------
// kernels: bitplane lane micro-benchmarks + perf-regression harness
// ---------------------------------------------------------------------------

/// Kept measurement invocations per kernel (after warmup).
const KERNEL_INVOCATIONS: usize = 5;
/// Leading invocations discarded (first-touch, branch/µop caches).
const KERNEL_WARMUP: usize = 1;

/// One kernel's measurement: fixed iteration count, per-invocation mean.
struct KernelResult {
    name: &'static str,
    shape: String,
    iters: usize,
    /// mean ns per iteration over the kept invocations
    ns_per_iter: f64,
    /// best (minimum) kept invocation — the low-noise number
    min_ns_per_iter: f64,
    /// 64-bit plane words streamed per second at the mean rate
    words_per_sec: f64,
    /// deterministic output fingerprint; equality across lane widths is
    /// the exactness contract measured, not assumed
    checksum: f64,
}

/// Time `f` for `KERNEL_WARMUP + KERNEL_INVOCATIONS` invocations of
/// `iters` calls each, discarding the warmup. `f` returns a checksum so
/// the optimizer cannot dead-code the kernel; `black_box` pins it.
fn run_kernel(
    name: &'static str,
    shape: String,
    iters: usize,
    words_per_iter: usize,
    mut f: impl FnMut() -> f64,
) -> KernelResult {
    let mut kept: Vec<f64> = Vec::with_capacity(KERNEL_INVOCATIONS);
    let mut checksum = 0.0f64;
    for inv in 0..KERNEL_WARMUP + KERNEL_INVOCATIONS {
        let t0 = Instant::now();
        for _ in 0..iters {
            // black_box pins every call's result; keeping the *last* value
            // (identical every call — the kernels are deterministic) keeps
            // the checksum independent of the iteration count, so groups
            // benched at different budgets still compare bit-for-bit
            checksum = std::hint::black_box(f());
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters.max(1) as f64;
        if inv >= KERNEL_WARMUP {
            kept.push(ns);
        }
    }
    let mean = kept.iter().sum::<f64>() / kept.len() as f64;
    let min = kept.iter().cloned().fold(f64::INFINITY, f64::min);
    KernelResult {
        name,
        shape,
        iters,
        ns_per_iter: mean,
        min_ns_per_iter: min,
        words_per_sec: words_per_iter as f64 * 1e9 / mean.max(1e-9),
        checksum,
    }
}

/// The `kernels` bench section: a 1/4/8 lane-width sweep of the hot
/// bitplane kernels (forward dot + GEMM, multi-bitplane GEMM, backward
/// dX/dW, row packing) against their scalar baselines, written to
/// `BENCH_kernels.json` (schema `bench_kernels.v1`, documented in the
/// README). With `--baseline <json>` the run additionally diffs ns/iter
/// per kernel against that file and returns an error (nonzero exit) when
/// any kernel regresses past `threshold`.
fn bench_kernels(baseline: Option<&str>, threshold: f64) -> anyhow::Result<()> {
    println!("== kernels: bitplane lane micro-benchmarks (BENCH_kernels.json) ==");
    println!(
        "(fixed iterations x {KERNEL_INVOCATIONS} invocations, \
         {KERNEL_WARMUP} warmup invocation discarded; lane width {} words)\n",
        bitplane::LANE_WORDS
    );
    let mut rng = Prng::new(42);
    let mut results: Vec<KernelResult> = Vec::new();
    let tern = |rng: &mut Prng, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.below(3) as f32 - 1.0).collect()
    };

    // --- gated_dot width sweep: a single long ternary dot ---
    let m = 16_384usize;
    let dwords = bitplane::words_for(m);
    let stride = bitplane::words_stride(m);
    let (av, wv) = (tern(&mut rng, m), tern(&mut rng, m));
    let (mut a_s, mut a_z) = (vec![0u64; stride], vec![0u64; stride]);
    let (mut w_s, mut w_z) = (vec![0u64; stride], vec![0u64; stride]);
    bitplane::pack_row_into(&av, &mut a_s, &mut a_z);
    bitplane::pack_row_into(&wv, &mut w_s, &mut w_z);
    let dshape = format!("m={m}");
    const DOT_ITERS: usize = 2000;
    let dot_sum = |d: i64, act: u64| d as f64 + act as f64;
    results.push(run_kernel("dot/scalar", dshape.clone(), DOT_ITERS, dwords, || {
        let (d, act) = bitplane::gated_dot_scalar(&a_s, &a_z, &w_s, &w_z);
        dot_sum(d, act)
    }));
    results.push(run_kernel("dot/lane1", dshape.clone(), DOT_ITERS, dwords, || {
        let (d, act) = bitplane::gated_dot_lanes::<1>(&a_s, &a_z, &w_s, &w_z);
        dot_sum(d, act)
    }));
    results.push(run_kernel("dot/lane4", dshape.clone(), DOT_ITERS, dwords, || {
        let (d, act) = bitplane::gated_dot_lanes::<4>(&a_s, &a_z, &w_s, &w_z);
        dot_sum(d, act)
    }));
    results.push(run_kernel("dot/lane8", dshape.clone(), DOT_ITERS, dwords, || {
        let (d, act) = bitplane::gated_dot_lanes::<8>(&a_s, &a_z, &w_s, &w_z);
        dot_sum(d, act)
    }));

    // --- packed GEMM width sweep (the forward hot path) ---
    let (rows, gm, gn) = (32usize, 2048usize, 128usize);
    let aw = tern(&mut rng, rows * gm);
    let ww = tern(&mut rng, gm * gn);
    let cols = BitplaneCols::pack_cols(&ww, gm, gn);
    let mut pack = PackScratch::new();
    pack.pack_rows(&aw, rows, gm);
    let mut out = vec![0.0f32; rows * gn];
    let gwords = rows * gn * bitplane::words_for(gm);
    let gshape = format!("{rows}x{gm}x{gn}");
    let out_sum = |o: &[f32]| o.iter().map(|&v| v as f64).sum::<f64>();
    results.push(run_kernel("gemm/scalar_oracle", gshape.clone(), 2, gwords, || {
        bitplane::scalar_gemm(&aw, rows, &ww, gm, gn, &mut out);
        out_sum(&out)
    }));
    results.push(run_kernel("gemm/lane1", gshape.clone(), 20, gwords, || {
        let mut stats = GateStats::default();
        bitplane::gated_packed_rows_range_width::<1>(&pack, 0, rows, &cols, &mut out, &mut stats);
        out_sum(&out)
    }));
    results.push(run_kernel("gemm/lane4", gshape.clone(), 20, gwords, || {
        let mut stats = GateStats::default();
        bitplane::gated_packed_rows_range_width::<4>(&pack, 0, rows, &cols, &mut out, &mut stats);
        out_sum(&out)
    }));
    results.push(run_kernel("gemm/lane8", gshape.clone(), 20, gwords, || {
        let mut stats = GateStats::default();
        bitplane::gated_packed_rows_range_width::<8>(&pack, 0, rows, &cols, &mut out, &mut stats);
        out_sum(&out)
    }));

    // --- multi-bitplane GEMM (Z_N operands, digit planes live) ---
    let space = DiscreteSpace::new(2);
    let states = space.states();
    let aq: Vec<f32> = (0..rows * gm).map(|_| states[rng.below(states.len())]).collect();
    let wq: Vec<f32> = (0..gm * gn).map(|_| states[rng.below(states.len())]).collect();
    let colsq = BitplaneCols::pack_cols_space(&wq, gm, gn, space);
    let mut packq = PackScratch::new();
    packq.pack_rows_spec(&aq, rows, gm, PlaneSpec::for_space(space));
    results.push(run_kernel("gemm_multi/lane8", gshape.clone(), 10, gwords, || {
        let mut stats = GateStats::default();
        bitplane::gated_packed_rows_range(&packq, 0, rows, &colsq, &mut out, &mut stats);
        out_sum(&out)
    }));

    // --- backward dX = dY·Wᵀ-shape kernel vs its f64 oracle ---
    let af: Vec<f32> = (0..rows * gm).map(|_| rng.normal_f32()).collect();
    results.push(run_kernel("dx/packed", gshape.clone(), 10, gwords, || {
        backward::f32_rows_times_tern_cols(&af, rows, &cols, &mut out);
        out_sum(&out)
    }));
    results.push(run_kernel("dx/scalar_oracle", gshape.clone(), 2, gwords, || {
        backward::f32_rows_times_tern_cols_oracle(&af, rows, &ww, gm, gn, &mut out);
        out_sum(&out)
    }));

    // --- backward dW accumulation vs its scalar oracle ---
    let dy: Vec<f32> = (0..rows * gn).map(|_| rng.normal_f32()).collect();
    let pwords = pack.words();
    let mut dwp = vec![0.0f64; pwords * 64 * gn];
    let dw_sum = |d: &[f64], lanes: usize| d[..lanes * gn].iter().sum::<f64>();
    results.push(run_kernel("dw/packed", gshape.clone(), 5, rows * dwords_of(gm), || {
        dwp.iter_mut().for_each(|d| *d = 0.0);
        backward::accum_dw_packed(&pack, rows, &dy, gn, 0, pwords, &mut dwp);
        dw_sum(&dwp, gm)
    }));
    let mut dws = vec![0.0f64; gm * gn];
    results.push(run_kernel("dw/scalar_oracle", gshape.clone(), 2, rows * dwords_of(gm), || {
        dws.iter_mut().for_each(|d| *d = 0.0);
        backward::accum_dw_scalar(&aw, rows, gm, &dy, gn, 0, gm, &mut dws);
        dw_sum(&dws, gm)
    }));

    // --- row packing throughput (activation boundary cost) ---
    let mut pack2 = PackScratch::new();
    results.push(run_kernel("pack/rows", gshape.clone(), 50, rows * dwords_of(gm), || {
        pack2.pack_rows(&aw, rows, gm);
        let (s, _) = pack2.row(0);
        s[0] as f64
    }));

    // --- sparsity sweep: dense lane vs tile-skip vs event-list across
    // synthetic occupancies. Rows are block-structured (live lanes first,
    // then zeros), so whole tiles genuinely rest — the shape ReLU-like
    // ternary activations take, and the one the occupancy maps exploit.
    // All three kernels are pinned bit-identical via the checksum groups.
    const SPARSE_CASES: [(f64, [&str; 3]); 4] = [
        (0.90, ["sparse0.90/lane", "sparse0.90/tile_skip", "sparse0.90/event_list"]),
        (0.50, ["sparse0.50/lane", "sparse0.50/tile_skip", "sparse0.50/event_list"]),
        (0.10, ["sparse0.10/lane", "sparse0.10/tile_skip", "sparse0.10/event_list"]),
        (0.02, ["sparse0.02/lane", "sparse0.02/tile_skip", "sparse0.02/event_list"]),
    ];
    let (srows, sm, sn) = (32usize, 4096usize, 64usize);
    let swords = srows * sn * bitplane::words_for(sm);
    let wsparse = tern(&mut rng, sm * sn);
    let scols = BitplaneCols::pack_cols(&wsparse, sm, sn);
    let mut sout = vec![0.0f32; srows * sn];
    for (occ, [lane_name, tile_name, event_name]) in SPARSE_CASES {
        let live = ((sm as f64 * occ).round() as usize).min(sm);
        let act: Vec<f32> = (0..srows)
            .flat_map(|_| {
                let mut row = vec![0.0f32; sm];
                for v in row[..live].iter_mut() {
                    *v = if rng.below(2) == 0 { -1.0 } else { 1.0 };
                }
                row
            })
            .collect();
        let mut spack = PackScratch::new();
        spack.pack_rows(&act, srows, sm);
        let sshape = format!("{srows}x{sm}x{sn} occ={occ:.2}");
        for (name, strat) in [
            (lane_name, KernelStrategy::Lane),
            (tile_name, KernelStrategy::TileSkip),
            (event_name, KernelStrategy::EventList),
        ] {
            results.push(run_kernel(name, sshape.clone(), 10, swords, || {
                let mut stats = GateStats::default();
                bitplane::gated_packed_rows_strategy(
                    &spack, 0, srows, &scols, &mut sout, &mut stats, strat,
                );
                out_sum(&sout)
            }));
        }
    }

    println!(
        "{:<20} {:>14} {:>7} {:>14} {:>14} {:>12}",
        "kernel", "shape", "iters", "ns/iter", "min ns/iter", "Gwords/s"
    );
    for r in &results {
        println!(
            "{:<20} {:>14} {:>7} {:>14.0} {:>14.0} {:>12.3}",
            r.name,
            r.shape,
            r.iters,
            r.ns_per_iter,
            r.min_ns_per_iter,
            r.words_per_sec / 1e9
        );
    }

    // the exactness contract, measured: every lane width (and the scalar
    // fallback) produced bit-identical outputs to its reference
    let sum_of = |name: &str| {
        results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.checksum.to_bits())
            .expect("kernel result present")
    };
    let exact_groups: &[&[&str]] = &[
        &["dot/scalar", "dot/lane1", "dot/lane4", "dot/lane8"],
        &["gemm/scalar_oracle", "gemm/lane1", "gemm/lane4", "gemm/lane8"],
        &["dx/packed", "dx/scalar_oracle"],
        &["dw/packed", "dw/scalar_oracle"],
        &["sparse0.90/lane", "sparse0.90/tile_skip", "sparse0.90/event_list"],
        &["sparse0.50/lane", "sparse0.50/tile_skip", "sparse0.50/event_list"],
        &["sparse0.10/lane", "sparse0.10/tile_skip", "sparse0.10/event_list"],
        &["sparse0.02/lane", "sparse0.02/tile_skip", "sparse0.02/event_list"],
    ];
    let mut exact = true;
    for group in exact_groups {
        let want = sum_of(group[0]);
        for name in &group[1..] {
            if sum_of(name) != want {
                exact = false;
                println!("EXACTNESS VIOLATION: {} != {}", name, group[0]);
            }
        }
    }
    println!("\nlane outputs bit-identical to scalar references: {exact}");

    let ns_of = |name: &str| {
        results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.ns_per_iter)
            .unwrap_or(f64::NAN)
    };
    let speedups = [
        ("dot_lane8_vs_scalar", ns_of("dot/scalar") / ns_of("dot/lane8")),
        ("dot_lane8_vs_lane1", ns_of("dot/lane1") / ns_of("dot/lane8")),
        ("gemm_lane8_vs_lane1", ns_of("gemm/lane1") / ns_of("gemm/lane8")),
        ("gemm_lane8_vs_scalar_oracle", ns_of("gemm/scalar_oracle") / ns_of("gemm/lane8")),
        ("dx_packed_vs_oracle", ns_of("dx/scalar_oracle") / ns_of("dx/packed")),
        ("dw_packed_vs_oracle", ns_of("dw/scalar_oracle") / ns_of("dw/packed")),
    ];
    for (k, v) in &speedups {
        println!("  {k:<30} {v:.2}x");
    }

    println!("\nsparsity sweep (vs dense lane path at the same occupancy):");
    let sparsity_sweep: Vec<Json> = SPARSE_CASES
        .iter()
        .map(|(occ, [lane, tile, event])| {
            let (l, t, e) = (ns_of(lane), ns_of(tile), ns_of(event));
            println!(
                "  occ {:>4.2}: tile_skip {:>5.2}x  event_list {:>5.2}x",
                occ,
                l / t.max(1e-9),
                l / e.max(1e-9)
            );
            Json::obj(vec![
                ("occupancy", Json::num(*occ)),
                ("lane_ns_per_iter", Json::num(l)),
                ("tile_skip_ns_per_iter", Json::num(t)),
                ("event_list_ns_per_iter", Json::num(e)),
                ("tile_skip_speedup", Json::num(l / t.max(1e-9))),
                ("event_list_speedup", Json::num(l / e.max(1e-9))),
            ])
        })
        .collect();

    let doc = Json::Obj(vec![
        ("schema".into(), Json::Str("bench_kernels.v1".into())),
        ("provenance".into(), json::provenance(bitplane::LANE_WORDS)),
        (
            "method".into(),
            Json::obj(vec![
                ("invocations", Json::num(KERNEL_INVOCATIONS as f64)),
                ("warmup_invocations", Json::num(KERNEL_WARMUP as f64)),
                (
                    "timing",
                    Json::str(
                        "per-iteration mean over kept invocations; \
                         min_ns_per_iter is the best kept invocation",
                    ),
                ),
            ]),
        ),
        ("lane_outputs_exact".into(), Json::Bool(exact)),
        (
            "kernels".into(),
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::str(r.name)),
                            ("shape", Json::str(&r.shape)),
                            ("iterations", Json::num(r.iters as f64)),
                            ("ns_per_iter", Json::num(r.ns_per_iter)),
                            ("min_ns_per_iter", Json::num(r.min_ns_per_iter)),
                            ("words_per_sec", Json::num(r.words_per_sec)),
                            ("checksum", Json::num(r.checksum)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedups".into(),
            Json::Obj(speedups.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect()),
        ),
        ("sparsity_sweep".into(), Json::Arr(sparsity_sweep)),
    ]);
    let text = doc.to_string();
    std::fs::write("BENCH_kernels.json", &text)?;
    if std::path::Path::new("../ROADMAP.md").exists() {
        std::fs::write("../BENCH_kernels.json", &text)?;
    }
    println!("\nwrote BENCH_kernels.json (schema bench_kernels.v1)\n");

    if !exact {
        anyhow::bail!("lane kernels diverged from their scalar references (see above)");
    }
    if let Some(path) = baseline {
        compare_with_baseline(&results, path, threshold)?;
    }
    Ok(())
}

/// Logical (unpadded) plane words of an `m`-lane operand — the work unit
/// the words/s rates are normalized by.
fn dwords_of(m: usize) -> usize {
    bitplane::words_for(m)
}

/// Compare this run's per-kernel `ns_per_iter` against a previous
/// `BENCH_kernels.json`. Kernels missing from the baseline (or recorded
/// as `null` — the checked-in placeholder) are skipped *visibly*; any
/// kernel slower than `baseline · (1 + threshold)` is a regression and
/// the function errors, turning into a nonzero process exit for CI.
fn compare_with_baseline(
    results: &[KernelResult],
    path: &str,
    threshold: f64,
) -> anyhow::Result<()> {
    println!("-- baseline compare: {path} (threshold {:.0}%) --", 100.0 * threshold);
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read baseline {path}: {e}"))?;
    let base = Json::parse(&text).map_err(anyhow::Error::msg)?;
    let kernels: &[Json] = base.get("kernels").and_then(Json::as_arr).unwrap_or(&[]);
    let lookup = |name: &str| -> Option<f64> {
        kernels
            .iter()
            .find(|k| k.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|k| k.get("ns_per_iter"))
            .and_then(Json::as_f64)
    };
    let mut compared = 0usize;
    let mut regressions: Vec<String> = Vec::new();
    for r in results {
        match lookup(r.name) {
            Some(b) if b > 0.0 => {
                compared += 1;
                let delta = r.ns_per_iter / b - 1.0;
                let verdict = if delta > threshold { "REGRESSION" } else { "ok" };
                println!(
                    "  {:<20} {:>12.0} -> {:>12.0} ns/iter  {:>+7.1}%  {verdict}",
                    r.name,
                    b,
                    r.ns_per_iter,
                    100.0 * delta
                );
                if delta > threshold {
                    regressions.push(format!("{} {:+.1}%", r.name, 100.0 * delta));
                }
            }
            _ => println!("  {:<20} no baseline measurement — skipped", r.name),
        }
    }
    if compared == 0 {
        println!("  (baseline holds no measured kernels — placeholder file; nothing compared)");
    }
    if !regressions.is_empty() {
        anyhow::bail!(
            "kernel perf regression past the {:.0}% threshold: {}",
            100.0 * threshold,
            regressions.join(", ")
        );
    }
    println!("  no regressions past the threshold ({compared} kernels compared)\n");
    Ok(())
}
