//! Serving-layer tests: the batching queue as pure virtual-clock logic,
//! partial-batch engine parity, and loopback end-to-end bit-identity.
//!
//! The queue tests never sleep — time is a u64 the test advances — so
//! every SLO race (max-wait vs max-batch, deadline expiry vs dispatch) is
//! pinned deterministically. The loopback tests exercise the real TCP
//! stack on 127.0.0.1:0 and assert the serving layer is *pure routing*:
//! logits served through any replica count are bit-for-bit the logits of
//! a direct `infer_batch` call on the same inputs.

use gxnor::coordinator::method::Method;
use gxnor::engine::NativeEngine;
use gxnor::nn::init::init_model;
use gxnor::nn::params::{ModelState, ParamDesc, ParamKind};
use gxnor::runtime::exec::ExecEngine;
use gxnor::serve::queue::{BatchQueue, CutReason, Offer, QueueConfig, NO_DEADLINE};
use gxnor::serve::service::{Client, ClientReply, ServeConfig, Service};
use gxnor::ternary::DiscreteSpace;
use gxnor::util::json::Json;
use gxnor::util::prng::Prng;

// ---------------------------------------------------------------------------
// BatchQueue: virtual-clock unit tests (no sockets, no sleeps)
// ---------------------------------------------------------------------------

fn qcfg(max_batch: usize, max_wait_ns: u64, bound: usize, deadline_ns: u64) -> QueueConfig {
    QueueConfig { max_batch, max_wait_ns, bound, deadline_ns }
}

#[test]
fn queue_cuts_on_max_batch_immediately() {
    let mut q: BatchQueue<u32> = BatchQueue::new(qcfg(4, 1_000_000, 64, 0));
    for i in 0..4u32 {
        assert!(matches!(q.offer(i, 10), Offer::Accepted { .. }));
    }
    // same instant as the offers: the size condition alone cuts
    let p = q.poll(10);
    let cut = p.batch.expect("full batch must cut");
    assert_eq!(cut.reason, CutReason::MaxBatch);
    assert_eq!(cut.tickets.len(), 4);
    assert!(q.is_empty());
    assert!(p.expired.is_empty());
    assert_eq!(p.next_event_ns, None);
}

#[test]
fn queue_cuts_on_max_wait_deadline() {
    let wait = 1_000u64;
    let mut q: BatchQueue<u32> = BatchQueue::new(qcfg(8, wait, 64, 0));
    q.offer(0, 100);
    q.offer(1, 150);
    q.offer(2, 400);
    // one tick before the oldest ticket's wait expires: no cut, and the
    // queue names exactly when it next needs attention
    let p = q.poll(100 + wait - 1);
    assert!(p.batch.is_none());
    assert_eq!(p.next_event_ns, Some(100 + wait));
    // at the deadline: everything queued flushes as one MaxWait cut
    let p = q.poll(100 + wait);
    let cut = p.batch.expect("max-wait must cut");
    assert_eq!(cut.reason, CutReason::MaxWait);
    assert_eq!(cut.tickets.len(), 3);
    assert!(q.is_empty());
}

#[test]
fn max_batch_wins_the_race_with_max_wait() {
    // both conditions hold at the same instant: the cut is size-bounded
    // (max_batch tickets, not "everything"), and labelled MaxBatch
    let wait = 500u64;
    let mut q: BatchQueue<u32> = BatchQueue::new(qcfg(2, wait, 64, 0));
    q.offer(0, 0);
    q.offer(1, 0);
    q.offer(2, 0);
    let p = q.poll(wait); // oldest has also waited exactly `wait`
    let cut = p.batch.expect("batch due");
    assert_eq!(cut.reason, CutReason::MaxBatch);
    assert_eq!(cut.tickets.len(), 2);
    assert_eq!(q.depth(), 1);
    // the remainder cuts as MaxWait (it arrived at 0 too)
    let p = q.poll(wait);
    let cut = p.batch.expect("remainder due");
    assert_eq!(cut.reason, CutReason::MaxWait);
    assert_eq!(cut.tickets.len(), 1);
}

#[test]
fn deadline_expiry_sheds_before_dispatch() {
    // deadline tighter than max-wait: tickets die in the queue and must
    // never appear in a cut
    let mut q: BatchQueue<u32> = BatchQueue::new(qcfg(8, 10_000, 64, 1_000));
    q.offer(0, 0); // expires at 1_000
    q.offer(1, 600); // expires at 1_600
    let p = q.poll(1_200);
    assert_eq!(p.expired.len(), 1);
    assert_eq!(p.expired[0].payload, 0);
    assert!(p.batch.is_none());
    assert_eq!(q.depth(), 1);
    // the survivor's deadline is the next event (sooner than its wait cut)
    assert_eq!(p.next_event_ns, Some(1_600));
    let p = q.poll(1_600);
    assert_eq!(p.expired.len(), 1);
    assert_eq!(p.expired[0].payload, 1);
    assert!(q.is_empty());
}

#[test]
fn expired_tickets_do_not_count_toward_a_cut() {
    // 4 queued, max_batch 4, but one is dead by poll time: the cut must
    // not fire on stale size (3 live < 4)
    let mut q: BatchQueue<u32> = BatchQueue::new(qcfg(4, 100_000, 64, 0));
    let dl = 500u64;
    q.offer_deadline(0, 0, dl);
    q.offer_deadline(1, 0, NO_DEADLINE);
    q.offer_deadline(2, 0, NO_DEADLINE);
    q.offer_deadline(3, 0, NO_DEADLINE);
    let p = q.poll(600);
    assert_eq!(p.expired.len(), 1);
    assert!(p.batch.is_none(), "3 live tickets must not cut as a 4-batch");
    assert_eq!(q.depth(), 3);
}

#[test]
fn queue_bound_rejects_with_depth_and_payload() {
    let mut q: BatchQueue<u32> = BatchQueue::new(qcfg(2, 1_000, 3, 0));
    for i in 0..3u32 {
        assert!(matches!(q.offer(i, 0), Offer::Accepted { .. }));
    }
    match q.offer(99, 1) {
        Offer::Shed { payload, depth } => {
            // the payload comes back intact (the service replies on its
            // channel) along with the depth the client is told about
            assert_eq!(payload, 99);
            assert_eq!(depth, 3);
        }
        Offer::Accepted { .. } => panic!("bound must shed"),
    }
    assert_eq!(q.depth(), 3, "shed arrival must not enter the queue");
}

#[test]
fn fifo_order_within_and_across_batches() {
    let mut q: BatchQueue<u64> = BatchQueue::new(qcfg(4, 1_000, 64, 0));
    for i in 0..11u64 {
        q.offer(i, i); // strictly increasing arrival times
    }
    let mut seen: Vec<u64> = Vec::new();
    let p = q.poll(20);
    let cut = p.batch.unwrap();
    assert_eq!(cut.reason, CutReason::MaxBatch);
    seen.extend(cut.tickets.iter().map(|t| t.payload));
    let cut = q.poll(20).batch.unwrap();
    seen.extend(cut.tickets.iter().map(|t| t.payload));
    // 3 left, below max_batch: they flush when the oldest (arrived t=8)
    // hits its wait deadline
    assert!(q.poll(20).batch.is_none());
    let cut = q.poll(8 + 1_000).batch.unwrap();
    assert_eq!(cut.reason, CutReason::MaxWait);
    seen.extend(cut.tickets.iter().map(|t| t.payload));
    assert_eq!(seen, (0..11).collect::<Vec<u64>>());
    // seq mirrors arrival order too
    assert!(cut.tickets.windows(2).all(|w| w[0].seq < w[1].seq));
}

// ---------------------------------------------------------------------------
// Engine: partial batches (the relaxation serving depends on)
// ---------------------------------------------------------------------------

fn tiny_mlp_model(seed: u64) -> ModelState {
    let d = |name: &str, shape: Vec<usize>, kind, layer| ParamDesc {
        name: name.into(),
        shape,
        kind,
        layer,
    };
    use ParamKind::*;
    init_model(
        vec![
            d("W0", vec![784, 24], Weight, 0),
            d("gamma0", vec![24], Gamma, 0),
            d("beta0", vec![24], Beta, 0),
            d("W1", vec![24, 24], Weight, 1),
            d("gamma1", vec![24], Gamma, 1),
            d("beta1", vec![24], Beta, 1),
            d("W2", vec![24, 10], Weight, 2),
        ],
        vec!["rmean0".into(), "rvar0".into(), "rmean1".into(), "rvar1".into()],
        &[24, 24, 24, 24],
        DiscreteSpace::TERNARY,
        seed,
    )
}

fn sample(idx: u64, len: usize) -> Vec<f32> {
    let mut rng = Prng::new(0xA11CE ^ idx);
    (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

#[test]
fn partial_batch_matches_full_batch_prefix() {
    let model = tiny_mlp_model(3);
    let mut eng = NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 8, 10, 1).unwrap();
    let sl = eng.sample_len();
    let full: Vec<f32> = (0..8).flat_map(|i| sample(i, sl)).collect();
    let want = eng.infer_batch(&full).unwrap().to_vec();
    assert_eq!(want.len(), 8 * 10);
    for b in [1usize, 3, 5, 8] {
        let part = &full[..b * sl];
        let got = eng.infer_batch(part).unwrap().to_vec();
        assert_eq!(got.len(), b * 10, "partial batch returns b x n_classes");
        // bit-for-bit: per-sample independence means the prefix rows are
        // identical no matter how many neighbours ran alongside
        assert_eq!(got, want[..b * 10], "b={b}");
    }
    // shape errors stay errors
    assert!(eng.infer_batch(&full[..sl - 1]).is_err(), "ragged input");
    assert!(eng.infer_batch(&[]).is_err(), "empty input");
    let over: Vec<f32> = (0..9).flat_map(|i| sample(i, sl)).collect();
    assert!(eng.infer_batch(&over).is_err(), "over-capacity input");
    assert!(eng.supports_partial_batch());
}

// ---------------------------------------------------------------------------
// Loopback end-to-end: served logits == direct infer_batch, bit for bit
// ---------------------------------------------------------------------------

fn start_service(replicas: usize, cfg: ServeConfig) -> (Service, usize) {
    let model = tiny_mlp_model(7);
    let mut engines: Vec<Box<dyn ExecEngine + Send>> = Vec::new();
    let mut sample_len = 0;
    for _ in 0..replicas {
        let eng =
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, cfg.max_batch, 10, 1)
                .unwrap();
        sample_len = eng.sample_len();
        engines.push(Box::new(eng));
    }
    let svc = Service::start("127.0.0.1:0".parse().unwrap(), cfg, engines, sample_len).unwrap();
    (svc, sample_len)
}

#[test]
fn loopback_parity_replicas_1_2_4() {
    // reference: one big engine, all samples in a single direct call
    const N: usize = 24;
    let model = tiny_mlp_model(7);
    let mut reference =
        NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, N, 10, 1).unwrap();
    let sl = reference.sample_len();
    let all: Vec<f32> = (0..N as u64).flat_map(|i| sample(i, sl)).collect();
    let want = reference.infer_batch(&all).unwrap().to_vec();

    for replicas in [1usize, 2, 4] {
        let cfg = ServeConfig {
            replicas,
            max_batch: 4,
            max_wait_ms: 1.0,
            queue_bound: 256,
            deadline_ms: 0.0,
        };
        let (svc, sample_len) = start_service(replicas, cfg);
        assert_eq!(sample_len, sl);
        let addr = svc.addr;

        // 3 concurrent clients, 8 samples each — arbitrary batch packing
        // on the server side, exact logits expected regardless
        let results: Vec<(usize, Vec<f32>)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..3usize)
                .map(|c| {
                    s.spawn(move || {
                        let mut client = Client::connect(addr).unwrap();
                        let mut out = Vec::new();
                        for k in 0..8usize {
                            let idx = c * 8 + k;
                            let x = sample(idx as u64, sl);
                            match client.infer(&x).unwrap() {
                                ClientReply::Logits(l) => out.push((idx, l)),
                                other => panic!("request {idx}: unexpected reply {other:?}"),
                            }
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

        assert_eq!(results.len(), N, "replicas={replicas}");
        for (idx, logits) in &results {
            let expect = &want[idx * 10..(idx + 1) * 10];
            // bit-for-bit (f32 ==): serving is scheduling, not arithmetic
            assert_eq!(
                logits.as_slice(),
                expect,
                "replicas={replicas} sample={idx}: served logits diverge"
            );
        }

        // server-side accounting agrees before shutdown
        let mut probe = Client::connect(addr).unwrap();
        let stats = Json::parse(&probe.stats().unwrap()).unwrap();
        let n = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
        assert_eq!(n("completed"), N as f64, "replicas={replicas}");
        assert_eq!(n("protocol_errors"), 0.0);
        assert_eq!(n("internal_errors"), 0.0);
        assert_eq!(n("shed_queue"), 0.0);
        assert!(n("batches") >= 1.0);
        assert!(n("mean_batch_fill") >= 1.0 && n("mean_batch_fill") <= 4.0);
        drop(probe);
        svc.shutdown_and_join();
    }
}

#[test]
fn loopback_probes_stats_reset_and_shutdown_frame() {
    let cfg = ServeConfig {
        replicas: 1,
        max_batch: 2,
        max_wait_ms: 1.0,
        queue_bound: 16,
        deadline_ms: 0.0,
    };
    let (svc, sample_len) = start_service(1, cfg);
    let addr = svc.addr;
    let mut c = Client::connect(addr).unwrap();
    assert!(c.health().unwrap());
    assert!(c.ready().unwrap());

    // malformed INFER (wrong length) is a protocol error, connection stays up
    match c.infer(&vec![0.5f32; sample_len - 1]).unwrap() {
        ClientReply::Error(msg) => assert!(msg.contains("expected"), "{msg}"),
        other => panic!("unexpected {other:?}"),
    }
    // a good request still works on the same connection
    assert!(matches!(c.infer(&sample(0, sample_len)).unwrap(), ClientReply::Logits(_)));

    let stats = Json::parse(&c.stats().unwrap()).unwrap();
    assert_eq!(stats.get("protocol_errors").and_then(|v| v.as_f64()), Some(1.0));
    assert_eq!(stats.get("completed").and_then(|v| v.as_f64()), Some(1.0));

    c.stats_reset().unwrap();
    let stats = Json::parse(&c.stats().unwrap()).unwrap();
    assert_eq!(stats.get("protocol_errors").and_then(|v| v.as_f64()), Some(0.0));
    assert_eq!(stats.get("completed").and_then(|v| v.as_f64()), Some(0.0));

    // SHUTDOWN frame acks, then the whole service drains
    c.shutdown_server().unwrap();
    svc.join();
}

#[test]
fn loopback_per_request_deadline_expires_unserveable_work() {
    // no replicas consuming fast enough is hard to stage reliably, so
    // instead make the *wait* SLO looser than the request deadline: a
    // deadline shorter than max-wait in an otherwise idle queue must come
    // back DEADLINE (shed before dispatch), never a logits reply.
    let cfg = ServeConfig {
        replicas: 1,
        max_batch: 64, // never fills from one request
        max_wait_ms: 200.0,
        queue_bound: 64,
        deadline_ms: 0.0, // no server default; the request carries its own
    };
    let (svc, sample_len) = start_service(1, cfg);
    let mut c = Client::connect(svc.addr).unwrap();
    match c.infer_deadline(&sample(1, sample_len), 20).unwrap() {
        ClientReply::Deadline => {}
        other => panic!("expected DEADLINE, got {other:?}"),
    }
    let stats = Json::parse(&c.stats().unwrap()).unwrap();
    assert_eq!(stats.get("shed_deadline").and_then(|v| v.as_f64()), Some(1.0));
    assert_eq!(stats.get("completed").and_then(|v| v.as_f64()), Some(0.0));
    drop(c);
    svc.shutdown_and_join();
}
