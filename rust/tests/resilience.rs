//! Fault-tolerance suite: crash/resume bit-identity for training, the
//! retrying client's backoff/deadline/reconnect contract, and the
//! supervised serving path under an injected replica panic.
//!
//! Everything here is deterministic: faults come from a seeded
//! [`FaultPlan`] (the Nth batch panics, training aborts after epoch E),
//! backoff jitter from the repo's [`Prng`], and the resume tests compare
//! full serialized run state (model + optimizer + Prng + meta) bit for
//! bit — not just an accuracy number.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{NativeTrainer, TrainConfig};
use gxnor::engine::NativeEngine;
use gxnor::nn::init::init_model;
use gxnor::nn::params::{ModelState, ParamDesc, ParamKind};
use gxnor::runtime::exec::ExecEngine;
use gxnor::serve::replica::EngineFactory;
use gxnor::serve::service::{
    backoff_ms, f32s_to_bytes, frame, read_frame_blocking, write_frame, Client, ClientReply,
    ReadyInfo, RetryCfg, RetryClient, ServeConfig, Service,
};
use gxnor::ternary::DiscreteSpace;
use gxnor::util::fault::FaultPlan;
use gxnor::util::json::Json;
use gxnor::util::prng::Prng;

// ---------------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------------

fn d(name: &str, shape: Vec<usize>, kind: ParamKind, layer: usize) -> ParamDesc {
    ParamDesc { name: name.into(), shape, kind, layer }
}

/// Narrow MLP (784-H-H-10) descriptors in graph order.
fn mlp_descs(hidden: usize) -> (Vec<ParamDesc>, Vec<String>, Vec<usize>) {
    use ParamKind::*;
    (
        vec![
            d("W0", vec![784, hidden], Weight, 0),
            d("gamma0", vec![hidden], Gamma, 0),
            d("beta0", vec![hidden], Beta, 0),
            d("W1", vec![hidden, hidden], Weight, 1),
            d("gamma1", vec![hidden], Gamma, 1),
            d("beta1", vec![hidden], Beta, 1),
            d("W2", vec![hidden, 10], Weight, 2),
        ],
        vec!["rmean0".into(), "rvar0".into(), "rmean1".into(), "rvar1".into()],
        vec![hidden, hidden, hidden, hidden],
    )
}

/// 4-epoch native GXNOR run over the 160/64 synth split (5 steps/epoch).
fn base_cfg(threads: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        method: Method::Gxnor,
        threads,
        seed,
        epochs: 4,
        train_len: 160,
        test_len: 64,
        verbose: false,
        ..TrainConfig::default()
    }
}

fn ckpt_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("gxnor_resilience_{}_{tag}.ckpt", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

// ---------------------------------------------------------------------------
// Crash / resume: bit-identical continuation across thread counts
// ---------------------------------------------------------------------------

/// The acceptance gate: train 4 epochs uninterrupted vs. crash after
/// epoch 2 (injected) + resume from the periodic checkpoint. Final model
/// fingerprints AND full serialized run state (optimizer moments +
/// timestep, Prng, BN/EMA, meta) must match bit for bit — and the whole
/// equality must hold at every engine thread count, since per-epoch batch
/// streams and DST updates are thread-invariant by construction.
#[test]
fn resume_reproduces_uninterrupted_run_bit_for_bit() {
    let (descs, names, lens) = mlp_descs(24);
    let train = gxnor::data::open("synth_mnist", true, 160).unwrap();
    let test = gxnor::data::open("synth_mnist", false, 64).unwrap();
    let mut cross_thread: Option<(u64, Vec<u8>)> = None;

    for threads in [1usize, 2, 7] {
        // reference: the run nothing ever interrupted
        let mut full = NativeTrainer::from_descs(
            base_cfg(threads, 5),
            descs.clone(),
            names.clone(),
            &lens,
            32,
            10,
        )
        .unwrap();
        full.run(train.as_ref(), test.as_ref()).unwrap();
        let want_fp = full.model.fingerprint();
        let want_state = full.run_state_bytes(4);

        // crashing run: checkpoint every epoch, injected abort after epoch 2
        let path = ckpt_path(&format!("resume_t{threads}"));
        let mut cfg = base_cfg(threads, 5);
        cfg.checkpoint_every = 1;
        cfg.checkpoint_path = path.clone();
        cfg.faults = Some(Arc::new(FaultPlan::parse("train_crash=2").unwrap()));
        let mut crashed =
            NativeTrainer::from_descs(cfg, descs.clone(), names.clone(), &lens, 32, 10).unwrap();
        let err = crashed.run(train.as_ref(), test.as_ref()).unwrap_err();
        assert!(err.to_string().contains("train_crash"), "unexpected abort: {err}");

        // resume in a fresh trainer (no faults, no memory of the crash)
        let mut resumed = NativeTrainer::from_descs(
            base_cfg(threads, 5),
            descs.clone(),
            names.clone(),
            &lens,
            32,
            10,
        )
        .unwrap();
        let next = resumed.resume_from(&path).unwrap();
        assert_eq!(next, 2, "checkpoint should continue at epoch 2");
        resumed.run(train.as_ref(), test.as_ref()).unwrap();

        assert_eq!(resumed.model.fingerprint(), want_fp, "threads={threads}: fingerprint");
        assert_eq!(resumed.run_state_bytes(4), want_state, "threads={threads}: run state");

        // the run itself is thread-invariant, so all sweeps agree too
        match &cross_thread {
            None => cross_thread = Some((want_fp, want_state)),
            Some((fp, st)) => {
                assert_eq!(want_fp, *fp, "threads={threads}: cross-thread fingerprint");
                assert_eq!(&want_state, st, "threads={threads}: cross-thread run state");
            }
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Resume refuses checkpoints from a different run identity — silently
/// continuing someone else's training is worse than failing loudly.
#[test]
fn resume_rejects_mismatched_run_config() {
    let (descs, names, lens) = mlp_descs(16);
    let train = gxnor::data::open("synth_mnist", true, 160).unwrap();
    let test = gxnor::data::open("synth_mnist", false, 64).unwrap();

    let path = ckpt_path("mismatch");
    let mut cfg = base_cfg(1, 5);
    cfg.checkpoint_every = 1;
    cfg.checkpoint_path = path.clone();
    cfg.faults = Some(Arc::new(FaultPlan::parse("train_crash=1").unwrap()));
    let mut tr =
        NativeTrainer::from_descs(cfg, descs.clone(), names.clone(), &lens, 32, 10).unwrap();
    tr.run(train.as_ref(), test.as_ref()).unwrap_err();

    let try_resume = |cfg: TrainConfig| {
        let mut tr =
            NativeTrainer::from_descs(cfg, descs.clone(), names.clone(), &lens, 32, 10).unwrap();
        tr.resume_from(&path).unwrap_err().to_string()
    };
    assert!(try_resume(base_cfg(1, 6)).contains("seed"), "wrong seed must be rejected");
    let mut more_epochs = base_cfg(1, 5);
    more_epochs.epochs = 9;
    assert!(try_resume(more_epochs).contains("epochs"), "wrong epoch plan must be rejected");
    let mut other_r = base_cfg(1, 5);
    other_r.r = 0.75;
    assert!(try_resume(other_r).contains("(m,r,a)"), "wrong hyperparams must be rejected");
    // the matching config still resumes fine afterwards
    let mut ok = NativeTrainer::from_descs(
        base_cfg(1, 5),
        descs.clone(),
        names.clone(),
        &lens,
        32,
        10,
    )
    .unwrap();
    assert_eq!(ok.resume_from(&path).unwrap(), 1);
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Retry client: backoff math, budget, deadline, reconnect (fake servers)
// ---------------------------------------------------------------------------

#[test]
fn backoff_is_equal_jitter_capped_and_deterministic() {
    // attempt k sleeps uniformly in [cap_k/2, cap_k), cap_k = min(base·2^k, cap)
    let mut rng = Prng::new(7);
    for attempt in 0..12u32 {
        let capped = (10.0 * 2f64.powi(attempt as i32)).min(1_000.0);
        let v = backoff_ms(attempt, 10.0, 1_000.0, &mut rng);
        assert!(v >= capped / 2.0 && v < capped, "attempt {attempt}: {v} outside [{}, {capped})", capped / 2.0);
    }
    // absurd attempt counts stay finite at the cap (no 2^k overflow)
    let mut rng = Prng::new(1);
    let v = backoff_ms(u32::MAX, 10.0, 1_000.0, &mut rng);
    assert!(v.is_finite() && (500.0..1_000.0).contains(&v));
    // same seed → same sleep sequence (reproducible load runs); seeds diverge
    let seq = |seed: u64| -> Vec<f64> {
        let mut r = Prng::new(seed);
        (0..8u32).map(|k| backoff_ms(k, 10.0, 1_000.0, &mut r)).collect()
    };
    assert_eq!(seq(42), seq(42));
    assert_ne!(seq(42), seq(43));
}

/// A server that answers every INFER with RETRY exhausts exactly the
/// configured budget: retries=2 → 3 attempts on the wire, final reply
/// surfaces as `Retry` (the caller's signal that the budget is spent).
#[test]
fn retry_client_spends_exactly_its_budget() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut frames = 0u32;
        while let Ok((ty, _)) = read_frame_blocking(&mut s) {
            assert_eq!(ty, frame::INFER);
            frames += 1;
            write_frame(&mut s, frame::R_RETRY, &[]).unwrap();
        }
        frames // ends on client EOF
    });

    let rcfg = RetryCfg { retries: 2, backoff_base_ms: 1.0, backoff_cap_ms: 2.0, seed: 1 };
    let mut c = RetryClient::new(addr, rcfg);
    let (reply, attempts) = c.infer_retry(&[0.5f32; 4], 0).unwrap();
    assert_eq!(reply, ClientReply::Retry, "exhausted budget surfaces the final RETRY");
    assert_eq!(attempts, 2);
    drop(c);
    assert_eq!(server.join().unwrap(), 3, "first try + 2 retries on the wire");
}

/// The request deadline always beats the retry budget: a backoff sleep
/// that would cross the deadline is never taken, the client reports
/// DEADLINE instead of burning its (huge) budget.
#[test]
fn retry_client_deadline_wins_over_retry_budget() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        while read_frame_blocking(&mut s).is_ok() {
            write_frame(&mut s, frame::R_RETRY, &[]).unwrap();
        }
    });

    // backoff sleeps land in [100, 200) ms — always past the 60 ms deadline
    let rcfg =
        RetryCfg { retries: 100, backoff_base_ms: 200.0, backoff_cap_ms: 200.0, seed: 2 };
    let mut c = RetryClient::new(addr, rcfg);
    let t = Instant::now();
    let (reply, attempts) = c.infer_retry(&[0.25f32; 4], 60).unwrap();
    assert_eq!(reply, ClientReply::Deadline);
    assert!(attempts <= 1, "deadline must cut the retry loop short, used {attempts}");
    assert!(t.elapsed() < Duration::from_secs(5), "must not sleep through the budget");
    drop(c);
    server.join().unwrap();
}

/// Dropped connections are retryable: the client reconnects from scratch
/// each time and the attempt that finally lands gets its logits.
#[test]
fn retry_client_reconnects_after_dropped_connections() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let want = [1.0f32, -2.5];
    let server = thread::spawn(move || {
        // two connections die mid-request; the third behaves
        for conn in 0..3 {
            let (mut s, _) = listener.accept().unwrap();
            let (ty, _) = read_frame_blocking(&mut s).unwrap();
            assert_eq!(ty, frame::INFER);
            if conn == 2 {
                write_frame(&mut s, frame::R_LOGITS, &f32s_to_bytes(&want)).unwrap();
            } // else: drop without replying — the client sees EOF
        }
    });

    let rcfg = RetryCfg { retries: 5, backoff_base_ms: 1.0, backoff_cap_ms: 2.0, seed: 3 };
    let mut c = RetryClient::new(addr, rcfg);
    let (reply, attempts) = c.infer_retry(&[0.0f32; 4], 0).unwrap();
    assert_eq!(reply, ClientReply::Logits(want.to_vec()));
    assert_eq!(attempts, 2, "two dead connections, one good one");
    drop(c);
    server.join().unwrap();
}

/// A legacy 1-byte READY reply still decodes (degradation fields zeroed),
/// so old servers and new probes interoperate.
#[test]
fn ready_info_decodes_legacy_single_byte_reply() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let (ty, _) = read_frame_blocking(&mut s).unwrap();
        assert_eq!(ty, frame::READY);
        write_frame(&mut s, frame::R_READY, &[1]).unwrap();
    });
    let mut c = Client::connect(addr).unwrap();
    let info = c.ready_info().unwrap();
    assert_eq!(info, ReadyInfo { ready: true, degraded: false, live: 0, total: 0 });
    drop(c);
    server.join().unwrap();
}

// ---------------------------------------------------------------------------
// Supervised serving: injected replica panic, zero lost requests
// ---------------------------------------------------------------------------

fn tiny_mlp_model(seed: u64) -> ModelState {
    let (descs, names, lens) = mlp_descs(24);
    init_model(descs, names, &lens, DiscreteSpace::TERNARY, seed)
}

fn sample(idx: u64, len: usize) -> Vec<f32> {
    let mut rng = Prng::new(0xA11CE ^ idx);
    (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

/// The issue's acceptance scenario end-to-end: 2 replicas, FaultPlan
/// panics the worker serving the 2nd batch, and yet — through RETRY
/// replies and the client's idempotent resubmit — every request completes
/// with bit-exact logits, the accounting balances (nothing silently
/// lost), and the supervisor respawns the dead replica until READY
/// reports full strength again.
#[test]
fn supervised_service_survives_replica_panic_without_losing_requests() {
    const N: usize = 8;
    let model = Arc::new(tiny_mlp_model(7));

    // bit-exact reference: one big engine, all samples at once
    let mut reference =
        NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, N, 10, 1).unwrap();
    let sl = reference.sample_len();
    let all: Vec<f32> = (0..N as u64).flat_map(|i| sample(i, sl)).collect();
    let want = reference.infer_batch(&all).unwrap().to_vec();

    let mut engines: Vec<Box<dyn ExecEngine + Send>> = Vec::new();
    let mut sample_len = 0;
    for _ in 0..2 {
        let eng = NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 4, 10, 1).unwrap();
        sample_len = eng.sample_len();
        engines.push(Box::new(eng));
    }
    let factory: EngineFactory = {
        let model = Arc::clone(&model);
        Arc::new(move || {
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 4, 10, 1)
                .map(|e| Box::new(e) as Box<dyn ExecEngine + Send>)
                .map_err(|e| e.to_string())
        })
    };
    let faults = Some(Arc::new(FaultPlan::parse("replica_panic=2").unwrap()));
    let cfg = ServeConfig {
        replicas: 2,
        max_batch: 4,
        max_wait_ms: 0.5,
        queue_bound: 64,
        deadline_ms: 0.0,
    };
    let svc = Service::start_supervised(
        "127.0.0.1:0".parse().unwrap(),
        cfg,
        engines,
        Some(factory),
        faults,
        sample_len,
    )
    .unwrap();
    let addr = svc.addr;

    let mut probe = Client::connect(addr).unwrap();
    let info = probe.ready_info().unwrap();
    assert_eq!((info.ready, info.degraded, info.live, info.total), (true, false, 2, 2));

    // sequential requests: the 2nd dispatched batch panics its replica,
    // the retrying client resubmits, everything completes bit-exactly
    let rcfg = RetryCfg { retries: 3, backoff_base_ms: 1.0, backoff_cap_ms: 10.0, seed: 9 };
    let mut client = RetryClient::new(addr, rcfg);
    let mut retried = 0u64;
    for idx in 0..N as u64 {
        let x = sample(idx, sl);
        let (reply, attempts) = client.infer_retry(&x, 0).unwrap();
        retried += u64::from(attempts);
        match reply {
            ClientReply::Logits(l) => {
                let i = idx as usize;
                assert_eq!(l.as_slice(), &want[i * 10..(i + 1) * 10], "sample {idx} diverged");
            }
            other => panic!("request {idx}: unexpected reply {other:?}"),
        }
    }
    assert_eq!(retried, 1, "exactly the panicked batch needed a resubmit");

    // accounting balances: N completions, 1 errored attempt, 1 panic, and
    // no protocol/internal errors anywhere
    let stats = Json::parse(&probe.stats().unwrap()).unwrap();
    let n = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0);
    assert_eq!(n("completed"), N as f64);
    assert_eq!(n("errored"), 1.0);
    assert_eq!(n("replica_panics"), 1.0);
    assert_eq!(n("protocol_errors"), 0.0);
    assert_eq!(n("internal_errors"), 0.0);

    // the supervisor rebuilds the dead replica under backoff; READY
    // returns to full strength (live == total, not degraded)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = Json::parse(&probe.stats().unwrap()).unwrap();
        let restarts = stats.get("replica_restarts").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let info = probe.ready_info().unwrap();
        if restarts >= 1.0 && info.live == 2 && !info.degraded {
            assert!(info.ready);
            break;
        }
        assert!(
            Instant::now() < deadline,
            "supervisor never recovered: restarts={restarts} info={info:?}"
        );
        thread::sleep(Duration::from_millis(20));
    }

    // the respawned replica serves the same bits (same model, new engine)
    for idx in 0..N as u64 {
        let (reply, _) = client.infer_retry(&sample(idx, sl), 0).unwrap();
        let i = idx as usize;
        assert_eq!(
            reply,
            ClientReply::Logits(want[i * 10..(i + 1) * 10].to_vec()),
            "post-recovery sample {idx} diverged"
        );
    }

    drop(client);
    drop(probe);
    svc.shutdown_and_join();
}
