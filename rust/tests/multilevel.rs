//! Multi-level (`Z_N`, N ≥ 2) discrete spaces on the native engine — the
//! paper's unified-framework claim (eq. 2 / Fig. 13) executed rather than
//! special-cased:
//!
//! * multi-bitplane GEMM kernels vs the gated f64 scalar oracle, exact
//!   equality across `DiscreteSpace` levels and ragged shapes;
//! * the packed-domain DST on multi-bit layouts (straddling widths
//!   included), bit-identical to the f32 reference for any thread count;
//! * a grid-step finite-difference check of a multi-level native
//!   training step;
//! * packed vs scalar-oracle inference parity for `multi:N1,N2`;
//! * the device-free (N1, N2) levels sweep — no manifest, no PJRT.
//!
//! Everything here runs device-free. CI re-runs the file under
//! `GXNOR_THREADS=3` for shard-boundary coverage.

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{NativeTrainer, TrainBackend, TrainConfig};
use gxnor::engine::backward::{
    accum_dw_packed, accum_dw_scalar, f32_rows_times_tern_cols, f32_rows_times_tern_cols_oracle,
};
use gxnor::engine::bitplane::{
    gated_gemm_spec, scalar_gemm, BitplaneCols, GateStats, PackScratch, PlaneSpec,
};
use gxnor::engine::{NativeEngine, NativeTrainEngine};
use gxnor::nn::init::init_model;
use gxnor::nn::params::{ModelState, ParamDesc, ParamKind, ParamValue};
use gxnor::ptest::{property, Gen};
use gxnor::runtime::exec::{EngineKind, ExecEngine};
use gxnor::sweep;
use gxnor::ternary::{dst_update, dst_update_packed, DiscreteSpace, PackedTensor};
use gxnor::util::prng::Prng;

// ---------------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------------

fn d(name: &str, shape: Vec<usize>, kind: ParamKind, layer: usize) -> ParamDesc {
    ParamDesc { name: name.into(), shape, kind, layer }
}

/// Narrow MLP (784-H-H-10) descriptors in graph order.
fn mlp_descs(hidden: usize) -> (Vec<ParamDesc>, Vec<String>, Vec<usize>) {
    use ParamKind::*;
    (
        vec![
            d("W0", vec![784, hidden], Weight, 0),
            d("gamma0", vec![hidden], Gamma, 0),
            d("beta0", vec![hidden], Beta, 0),
            d("W1", vec![hidden, hidden], Weight, 1),
            d("gamma1", vec![hidden], Gamma, 1),
            d("beta1", vec![hidden], Beta, 1),
            d("W2", vec![hidden, 10], Weight, 2),
        ],
        vec!["rmean0".into(), "rvar0".into(), "rmean1".into(), "rvar1".into()],
        vec![hidden, hidden, hidden, hidden],
    )
}

/// Narrow cnn_mnist (cC5-MP2-cC5-MP2-fcFC-10) descriptors.
fn cnn_descs(c: usize, fc: usize) -> (Vec<ParamDesc>, Vec<String>, Vec<usize>) {
    use ParamKind::*;
    let flat = 4 * 4 * c;
    (
        vec![
            d("W0", vec![5, 5, 1, c], Weight, 0),
            d("gamma0", vec![c], Gamma, 0),
            d("beta0", vec![c], Beta, 0),
            d("W1", vec![5, 5, c, c], Weight, 1),
            d("gamma1", vec![c], Gamma, 1),
            d("beta1", vec![c], Beta, 1),
            d("W2", vec![flat, fc], Weight, 2),
            d("gamma2", vec![fc], Gamma, 2),
            d("beta2", vec![fc], Beta, 2),
            d("W3", vec![fc, 10], Weight, 3),
        ],
        vec![
            "rmean0".into(),
            "rvar0".into(),
            "rmean1".into(),
            "rvar1".into(),
            "rmean2".into(),
            "rvar2".into(),
        ],
        vec![c, c, c, c, fc, fc],
    )
}

fn model_in_space(
    descs: Vec<ParamDesc>,
    names: Vec<String>,
    lens: &[usize],
    n1: u32,
    seed: u64,
) -> ModelState {
    init_model(descs, names, lens, DiscreteSpace::new(n1), seed)
}

fn random_batch(batch: usize, len: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Prng::new(seed);
    let x = (0..batch * len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let y = (0..batch).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

/// Thread counts the determinism suite sweeps; CI adds GXNOR_THREADS=3.
fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1usize, 2, 7];
    if let Some(n) = std::env::var("GXNOR_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        if !counts.contains(&n) {
            counts.push(n);
        }
    }
    counts
}

// ---------------------------------------------------------------------------
// Kernel properties: multi-bitplane GEMMs vs the f64 scalar oracles
// ---------------------------------------------------------------------------

/// Forward GEMM: random grid operands from every (weight, activation)
/// space pairing `N ∈ 0..=4` (plus the paper's Z_6 weights), ragged
/// shapes straddling word and tile edges — the multi-bitplane kernel must
/// equal the f64 scalar GEMM **exactly**, and the gate tallies must count
/// exactly the both-nonzero lanes.
#[test]
fn prop_multi_bitplane_gemm_matches_f64_oracle() {
    property("multi bitplane gemm vs f64 oracle", 100, |g: &mut Gen| {
        let wn = *g.choose(&[0u32, 1, 2, 3, 4, 6]);
        let an = g.usize_in(0, 5) as u32;
        let (wspace, aspace) = (DiscreteSpace::new(wn), DiscreteSpace::new(an));
        let rows = g.usize_in(1, 6);
        let m = g.usize_in(1, 200);
        let n = g.usize_in(1, 18);
        let a: Vec<f32> = (0..rows * m)
            .map(|_| aspace.state(g.usize_in(0, aspace.n_states())))
            .collect();
        let w: Vec<f32> = (0..m * n)
            .map(|_| wspace.state(g.usize_in(0, wspace.n_states())))
            .collect();
        let cols = BitplaneCols::pack_cols_space(&w, m, n, wspace);
        let mut got = vec![0.0f32; rows * n];
        let mut want = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        let mut pack = PackScratch::new();
        gated_gemm_spec(
            &a,
            rows,
            PlaneSpec::for_space(aspace),
            &cols,
            &mut got,
            &mut stats,
            &mut pack,
        );
        scalar_gemm(&a, rows, &w, m, n, &mut want);
        if got != want {
            return Err(format!("w=Z_{wn} a=Z_{an} rows={rows} m={m} n={n}: kernel != oracle"));
        }
        let xnor: u64 = (0..rows)
            .flat_map(|r| (0..n).map(move |j| (r, j)))
            .map(|(r, j)| {
                (0..m).filter(|&i| a[r * m + i] != 0.0 && w[i * n + j] != 0.0).count() as u64
            })
            .sum();
        if stats.xnor != xnor || stats.total != (rows * m * n) as u64 {
            return Err(format!("w=Z_{wn} a=Z_{an}: gate tallies diverge"));
        }
        Ok(())
    });
}

/// Backward GEMMs with a multi-level discrete operand: `dX = dY·Wᵀ`
/// through multi-bitplane weight rows and `dW = Xᵀ·dY` streaming
/// multi-bitplane activation planes, vs their gated f64 scalar oracles —
/// exact equality, with the `dW` kernel additionally sharded into
/// {1, 2, 7} word ranges.
#[test]
fn prop_multi_backward_gemms_match_f64_oracle() {
    property("multi backward gemms vs f64 oracle", 80, |g: &mut Gen| {
        let n_space = g.usize_in(2, 5) as u32; // the genuinely multi-level widths
        let space = DiscreteSpace::new(n_space);
        let rows = g.usize_in(1, 6);
        let k = g.usize_in(1, 200);
        let n = g.usize_in(1, 14);

        // dX-shaped kernel: f32 rows × packed multi-level columns
        let a: Vec<f32> = (0..rows * k).map(|_| g.normal_f32()).collect();
        let t: Vec<f32> =
            (0..k * n).map(|_| space.state(g.usize_in(0, space.n_states()))).collect();
        let planes = BitplaneCols::pack_cols_space(&t, k, n, space);
        let mut got = vec![0.0f32; rows * n];
        let mut want = vec![0.0f32; rows * n];
        f32_rows_times_tern_cols(&a, rows, &planes, &mut got);
        f32_rows_times_tern_cols_oracle(&a, rows, &t, k, n, &mut want);
        if got != want {
            return Err(format!("N={n_space} rows={rows} k={k} n={n}: dX kernel != oracle"));
        }

        // the *row* packers' digit planes, through the same kernel:
        // dX = dY·Tᵀ via pack_rows_space / pack_rows_from_packed must
        // equal the oracle on the explicit transpose (this is the wrows
        // operand of the training engine's hidden-layer dX)
        let dyr: Vec<f32> = (0..rows * n).map(|_| g.normal_f32()).collect();
        let mut tt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                tt[j * k + i] = t[i * n + j];
            }
        }
        let mut want_t = vec![0.0f32; rows * k];
        f32_rows_times_tern_cols_oracle(&dyr, rows, &tt, n, k, &mut want_t);
        let wr = BitplaneCols::pack_rows_space(&t, k, n, space);
        let mut got_r = vec![0.0f32; rows * k];
        f32_rows_times_tern_cols(&dyr, rows, &wr, &mut got_r);
        if got_r != want_t {
            return Err(format!("N={n_space}: pack_rows_space dX != transposed oracle"));
        }
        let tp = PackedTensor::pack(&t, &[k, n], space);
        let wrp = BitplaneCols::pack_rows_from_packed(&tp, k, n);
        let mut got_p = vec![0.0f32; rows * k];
        f32_rows_times_tern_cols(&dyr, rows, &wrp, &mut got_p);
        if got_p != want_t {
            return Err(format!("N={n_space}: pack_rows_from_packed dX != transposed oracle"));
        }

        // dW-shaped kernel: packed multi-level rows × f32 cotangent rows
        let xt: Vec<f32> =
            (0..rows * k).map(|_| space.state(g.usize_in(0, space.n_states()))).collect();
        let dy: Vec<f32> = (0..rows * n).map(|_| g.normal_f32()).collect();
        let mut pack = PackScratch::new();
        pack.pack_rows_spec(&xt, rows, k, PlaneSpec::for_space(space));
        let words = pack.words();
        let mut oracle = vec![0.0f64; k * n];
        accum_dw_scalar(&xt, rows, k, &dy, n, 0, k, &mut oracle);
        for shards in [1usize, 2, 7] {
            let mut got = vec![0.0f64; k * n];
            let per = words.div_ceil(shards).max(1);
            let mut w0 = 0usize;
            while w0 < words {
                let w1 = (w0 + per).min(words);
                // `words` is the lane-padded stride: shards past the
                // logical fan-in clamp to empty slices (no gate bits there)
                let lane_lo = (w0 * 64).min(k);
                let lane_hi = (w1 * 64).min(k);
                accum_dw_packed(&pack, rows, &dy, n, w0, w1, &mut got[lane_lo * n..lane_hi * n]);
                w0 = w1;
            }
            if got != oracle {
                return Err(format!(
                    "N={n_space} rows={rows} k={k} n={n} shards={shards}: dW kernel != oracle"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Packed-domain DST on multi-bit layouts: thread-count bit-identity
// ---------------------------------------------------------------------------

/// `dst_update_packed` on multi-bit state layouts — the straddling 3-bit
/// Z_2 width and the word-dividing 4-bit Z_3 width, both above the
/// parallel threshold — must match the f32 reference update bit for bit
/// (states *and* statistics) for every thread count.
#[test]
fn multi_bit_packed_dst_is_bit_identical_across_threads() {
    for n in [2u32, 3] {
        let space = DiscreteSpace::new(n);
        let len = 250_007usize;
        let mut rng = Prng::new(500 + n as u64);
        let vals: Vec<f32> =
            (0..len).map(|_| space.state(rng.below(space.n_states()))).collect();
        let dw: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.8).collect();

        let mut w = vals.clone();
        let mut rng_ref = Prng::new(77);
        let want_stats = dst_update(&mut w, &dw, space, 3.0, &mut rng_ref, 1);

        for threads in thread_counts() {
            let mut p = PackedTensor::pack(&vals, &[len], space);
            let mut rng_t = Prng::new(77);
            let stats = dst_update_packed(&mut p, &dw, 3.0, &mut rng_t, threads);
            assert_eq!(stats, want_stats, "N={n} threads={threads}: stats diverge");
            assert_eq!(p.unpack(), w, "N={n} threads={threads}: states diverge");
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-level training step: grid-step finite differences
// ---------------------------------------------------------------------------

/// Finite-difference check of a multi-level native training step. The
/// loss is piecewise **quadratic** in any output-layer weight (logits are
/// linear in it and nothing downstream quantizes), so a central
/// difference over one grid step ±dz is exact wherever no hinge kink
/// falls inside the window — and the perturbed weights stay on the Z_N1
/// grid, so the whole check runs through the packed engine itself, digit
/// planes and all.
#[test]
fn fd_multi_level_step_output_layer_gradients() {
    let (n1, n2) = (3u32, 2u32); // dz = 0.25 weights, 5-level activations
    let method = Method::Multi { n1, n2 };
    let space = DiscreteSpace::new(n1);
    let dz = space.dz() as f64;
    let (descs, names, lens) = mlp_descs(16);
    let mut model = model_in_space(descs, names, &lens, n1, 31);
    let batch = 16usize;
    let mut eng =
        NativeTrainEngine::new("mlp", method, &model.descs, batch, 10, 0.5, 0.5, 2).unwrap();
    let (x, y) = random_batch(batch, 784, 93);
    let n_params = model.descs.len();
    let w_last = 6usize; // W2: hidden×10, no BN/quantizer after it
    let numel = model.descs[w_last].numel();

    let mut dirty = vec![true; n_params];
    let outs = eng.step(&x, &y, batch, &model, &mut dirty).unwrap();
    let grads = outs[3 + w_last].clone();

    let mut loss_at =
        |j: usize, val: f32, model: &mut ModelState, eng: &mut NativeTrainEngine| -> f64 {
            if let ParamValue::Discrete(p) = &mut model.values[w_last] {
                p.set(j, val);
            }
            let mut dirty = vec![false; n_params];
            dirty[w_last] = true; // the perturbed tensor must repack
            let o = eng.step(&x, &y, batch, model, &mut dirty).unwrap();
            o[0][0] as f64
        };

    let mut rng = Prng::new(7);
    let mut checked = 0usize;
    let mut passed = 0usize;
    for _ in 0..24 {
        let j = rng.below(numel);
        let orig = match &model.values[w_last] {
            ParamValue::Discrete(p) => p.get(j),
            _ => unreachable!("multi-level weights are packed"),
        };
        let (plus, minus) = (orig as f64 + dz, orig as f64 - dz);
        if plus > 1.0 + 1e-6 || minus < -1.0 - 1e-6 {
            continue; // no symmetric on-grid window at the boundary
        }
        let lp = loss_at(j, plus as f32, &mut model, &mut eng);
        let lm = loss_at(j, minus as f32, &mut model, &mut eng);
        if let ParamValue::Discrete(p) = &mut model.values[w_last] {
            p.set(j, orig);
        }
        let fd = (lp - lm) / (2.0 * dz);
        let an = grads[j] as f64;
        checked += 1;
        // the rare hinge kink inside a ±dz window perturbs fd by up to
        // ~dz·x²/valid per crossing row; the loose ceiling still catches
        // any structural bug (sign, transpose, scale) outright
        let tol = 0.08 * fd.abs().max(an.abs()) + 0.05;
        if (fd - an).abs() <= tol {
            passed += 1;
        }
        assert!(
            (fd - an).abs() <= 0.5,
            "W2 elem {j}: analytic {an:.5} vs FD {fd:.5} — structural mismatch"
        );
    }
    assert!(checked >= 12, "FD check exercised too few elements ({checked})");
    assert!(
        passed * 10 >= checked * 9,
        "only {passed}/{checked} FD probes within tolerance"
    );
}

// ---------------------------------------------------------------------------
// Multi-level inference: packed path vs the scalar oracle
// ---------------------------------------------------------------------------

/// Every `multi:N1,N2` forward must run the packed path on hidden layers
/// and agree **exactly** with the per-element scalar oracle — the packed
/// dot is an exact scaled integer, so even f32 logits match bit for bit.
#[test]
fn multi_inference_packed_path_matches_scalar_oracle() {
    for (n1, n2) in [(2u32, 2u32), (3, 2), (1, 3), (0, 2), (6, 4)] {
        let method = Method::Multi { n1, n2 };
        let (descs, names, lens) = mlp_descs(16);
        let model = model_in_space(descs, names, &lens, n1, 60 + n1 as u64);
        let mut packed = NativeEngine::from_model("mlp", method, &model, 0.5, 3, 10, 2).unwrap();
        let mut oracle = NativeEngine::from_model("mlp", method, &model, 0.5, 3, 10, 1).unwrap();
        oracle.force_scalar_path();
        assert!(
            packed.has_packed_layers(),
            "multi:{n1},{n2} must run packed hidden layers (dead scalar fallback?)"
        );
        assert!(!oracle.has_packed_layers());
        let (x, _) = random_batch(3, 784, 11 + n1 as u64);
        let a = packed.infer_batch(&x).unwrap().to_vec();
        let b = oracle.infer_batch(&x).unwrap().to_vec();
        assert_eq!(a, b, "multi:{n1},{n2}: packed logits != scalar oracle");
    }
}

/// Same exact-parity claim for the conv topology: multi-level packed
/// im2col vs the per-pixel scalar walk.
#[test]
fn multi_conv_inference_matches_scalar_oracle() {
    let method = Method::Multi { n1: 2, n2: 2 };
    let (descs, names, lens) = cnn_descs(6, 8);
    let model = model_in_space(descs, names, &lens, 2, 83);
    let mut packed =
        NativeEngine::from_model("cnn_mnist", method, &model, 0.5, 2, 10, 2).unwrap();
    let mut oracle =
        NativeEngine::from_model("cnn_mnist", method, &model, 0.5, 2, 10, 1).unwrap();
    oracle.force_scalar_path();
    assert!(packed.has_packed_layers());
    let (x, _) = random_batch(2, 28 * 28, 19);
    let a = packed.infer_batch(&x).unwrap().to_vec();
    let b = oracle.infer_batch(&x).unwrap().to_vec();
    assert_eq!(a, b, "multi conv: packed logits != scalar oracle");
}

// ---------------------------------------------------------------------------
// The engine accepts every multi space; the sweep runs device-free
// ---------------------------------------------------------------------------

/// The acceptance criterion verbatim: `NativeTrainEngine::new` accepts
/// **every** `Method::Multi` space (the `n_states > 3` rejection is gone).
#[test]
fn train_engine_accepts_every_multi_space() {
    for n1 in 0..=6u32 {
        for n2 in 0..=4u32 {
            let (descs, _, _) = mlp_descs(8);
            NativeTrainEngine::new("mlp", Method::Multi { n1, n2 }, &descs, 4, 10, 0.5, 0.5, 1)
                .unwrap_or_else(|e| panic!("multi:{n1},{n2} rejected: {e}"));
        }
    }
}

/// `sweep --param levels --engine native`, in-process: the (N1, N2) grid
/// completes with **no manifest and no PJRT client**, and each point
/// carries its (n1, n2) pair explicitly.
#[test]
fn sweep_levels_runs_device_free() {
    let mut backend = TrainBackend::Native { manifest: None };
    let base = TrainConfig {
        epochs: 1,
        train_len: 120,
        test_len: 40,
        batch: 40,
        engine: EngineKind::Native,
        threads: 2,
        verbose: false,
        ..Default::default()
    };
    let grid = [(1u32, 1u32), (2, 2)];
    let points = sweep::sweep_levels(&mut backend, &base, &grid).unwrap();
    assert_eq!(points.len(), 2);
    assert_eq!(points[0].levels, Some((1, 1)));
    assert_eq!(points[1].levels, Some((2, 2)));
    assert!(points.iter().all(|p| p.value.is_none()));
    assert!(points.iter().all(|p| (0.0..=1.0).contains(&p.test_acc)));
    let table = sweep::render_table("fig13", &points);
    assert!(table.contains("N1=2,N2=2") && table.contains(" N1 "), "{table}");
    let csv = sweep::render_csv(&points);
    assert!(csv.contains(",2,2,"), "{csv}");
}

/// End-to-end: a short multi-level run actually trains — loss finite and
/// decreasing-ish, weights stay on the Z_N1 grid, every state count
/// reachable, and the report shows zero f32 weight mirrors.
#[test]
fn multi_level_native_training_stays_packed_and_on_grid() {
    let (descs, names, lens) = mlp_descs(24);
    let cfg = TrainConfig {
        method: Method::Multi { n1: 2, n2: 2 },
        threads: 0,
        seed: 42,
        train_len: 200,
        test_len: 80,
        epochs: 2,
        verbose: false,
        ..Default::default()
    };
    let mut tr = NativeTrainer::from_descs(cfg, descs, names, &lens, 25, 10).unwrap();
    let train = gxnor::data::open("synth_mnist", true, 200).unwrap();
    let test = gxnor::data::open("synth_mnist", false, 80).unwrap();
    let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
    assert!(report.final_train_loss.is_finite());
    assert_eq!(report.weight_f32_mirror_bytes, 0);
    assert_eq!(report.hidden_fp32_bytes, 0);
    assert!(tr.transitioned_update_count() > 0, "multi-level DST never moved a state");
    assert!(tr.repack_count() <= tr.transitioned_update_count());
    // weights on the 5-state grid, with states actually used
    let space = DiscreteSpace::new(2);
    for v in &tr.model.values {
        if let ParamValue::Discrete(p) = v {
            assert_eq!(p.space(), space);
            let h = p.histogram();
            assert_eq!(h.len(), 5);
            assert_eq!(h.iter().sum::<u64>(), p.len() as u64);
        }
    }
}
