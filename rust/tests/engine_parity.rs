//! Engine parity and packed-kernel property tests.
//!
//! The native gated-XNOR engine runs without a PJRT device, so most of
//! this file executes everywhere; the XLA-vs-native parity tests gate on
//! `artifacts/manifest.json` (plus a real PJRT client) and skip visibly
//! otherwise, like the rest of the integration suite.

use gxnor::coordinator::checkpoint;
use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{evaluate_engine, TrainConfig, Trainer};
use gxnor::data::{self, Dataset};
use gxnor::engine::bitplane::{gated_xnor_gemm, scalar_gemm, BitplaneCols, GateStats, PackScratch};
use gxnor::engine::NativeEngine;
use gxnor::hwsim::counts::{gate_rate_matches, gxnor_resting_probability};
use gxnor::nn::init::init_model;
use gxnor::nn::params::{ModelState, ParamDesc, ParamKind};
use gxnor::ptest::{property, Gen};
use gxnor::runtime::client::Runtime;
use gxnor::runtime::exec::ExecEngine;
use gxnor::runtime::manifest::Manifest;
use gxnor::ternary::DiscreteSpace;

// ---------------------------------------------------------------------------
// Properties of the packed kernel (no artifacts needed)
// ---------------------------------------------------------------------------

/// The gated XNOR kernel must match a scalar reference GEMM for random
/// packed tensors drawn from every `DiscreteSpace`. Spaces with more than
/// three states are mapped through their ternary sign component (the
/// planes the kernel consumes: sign + nonzero); for N <= 1 the mapping is
/// the identity, i.e. the kernel computes the exact grid dot product.
#[test]
fn prop_gated_xnor_matches_scalar_gemm_all_spaces() {
    property("gated xnor vs scalar gemm", 120, |g: &mut Gen| {
        let n_space = g.usize_in(0, 7) as u32;
        let space = DiscreteSpace::new(n_space);
        let rows = g.usize_in(1, 6);
        let m = g.usize_in(1, 200);
        let n = g.usize_in(1, 24);
        let tern = |v: f32| {
            if v > 0.0 {
                1.0
            } else if v < 0.0 {
                -1.0
            } else {
                0.0
            }
        };
        let a: Vec<f32> = (0..rows * m)
            .map(|_| tern(space.state(g.usize_in(0, space.n_states()))))
            .collect();
        let w: Vec<f32> = (0..m * n)
            .map(|_| tern(space.state(g.usize_in(0, space.n_states()))))
            .collect();
        let cols = BitplaneCols::pack_cols(&w, m, n);
        let mut got = vec![0.0f32; rows * n];
        let mut want = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, rows, &cols, &mut got, &mut stats, &mut PackScratch::new());
        scalar_gemm(&a, rows, &w, m, n, &mut want);
        if got != want {
            return Err(format!("N={n_space} rows={rows} m={m} n={n}: kernel != reference"));
        }
        // counting identities
        if stats.total != (rows * m * n) as u64 {
            return Err("total connections miscounted".into());
        }
        if stats.xnor > stats.total {
            return Err("more XNOR ops than connections".into());
        }
        Ok(())
    });
}

/// Measured gate rates from the kernel must track the Table 2 analytic
/// prediction computed from the tensors' actual zero fractions.
#[test]
fn prop_gate_rate_tracks_analytic_prediction() {
    property("gate rate vs Table 2", 40, |g: &mut Gen| {
        let rows = 32;
        let m = g.usize_in(64, 256);
        let n = g.usize_in(16, 64);
        // biased ternary draws exercise non-uniform state distributions
        let p_zero = g.f32_in(0.1, 0.6);
        let mut draw = |g: &mut Gen| {
            let u = g.unit_f32();
            if u < p_zero {
                0.0
            } else if u < p_zero + (1.0 - p_zero) / 2.0 {
                1.0
            } else {
                -1.0
            }
        };
        let a: Vec<f32> = (0..rows * m).map(|_| draw(g)).collect();
        let w: Vec<f32> = (0..m * n).map(|_| draw(g)).collect();
        let cols = BitplaneCols::pack_cols(&w, m, n);
        let mut out = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        gated_xnor_gemm(&a, rows, &cols, &mut out, &mut stats, &mut PackScratch::new());
        let pw0 = w.iter().filter(|&&v| v == 0.0).count() as f64 / w.len() as f64;
        let px0 = stats.x_zero_fraction();
        if !gate_rate_matches(stats.resting_rate(), pw0, px0, 0.02) {
            return Err(format!(
                "measured {:.4} vs analytic {:.4} (pw0 {pw0:.3} px0 {px0:.3})",
                stats.resting_rate(),
                gxnor_resting_probability(pw0, px0)
            ));
        }
        Ok(())
    });
}

/// GateStats invariants across every execution path: `xnor + resting ==
/// total`, exact eval/activation tallies, and bit-identical stats *and*
/// outputs across lane widths {1, 4, 8}, the three kernel strategies
/// (lane, tile-skip, event-list), and multi-bit `PlaneSpec`s — with the
/// f64 scalar GEMM as the output oracle. This is what keeps the sparse
/// paths from silently miscounting the ops hwsim consumes.
#[test]
fn prop_gate_stats_invariant_across_widths_and_strategies() {
    use gxnor::engine::bitplane::{
        gated_packed_rows_range_width, gated_packed_rows_strategy, KernelStrategy, PlaneSpec,
    };
    property("GateStats width/strategy invariance", 60, |g: &mut Gen| {
        // ternary and multi-bit spaces (all contain the zero state)
        let n_space = g.usize_in(1, 4) as u32;
        let space = DiscreteSpace::new(n_space);
        let rows = g.usize_in(1, 5);
        let m = g.usize_in(1, 700);
        let n = g.usize_in(1, 20);
        // extra zero bias so sparse rows — and fully resting tiles — occur
        let p_zero = g.f32_in(0.0, 0.9);
        let states = space.states();
        let mut draw = |g: &mut Gen| {
            if g.unit_f32() < p_zero {
                0.0
            } else {
                states[g.usize_in(0, states.len())]
            }
        };
        let a: Vec<f32> = (0..rows * m).map(|_| draw(g)).collect();
        let w: Vec<f32> = (0..m * n).map(|_| draw(g)).collect();
        let cols = BitplaneCols::pack_cols_space(&w, m, n, space);
        let mut pack = PackScratch::new();
        pack.pack_rows_spec(&a, rows, m, PlaneSpec::for_space(space));
        let mut want = vec![0.0f32; rows * n];
        scalar_gemm(&a, rows, &w, m, n, &mut want);

        let mut variants: Vec<(&'static str, Vec<f32>, GateStats)> = Vec::new();
        let mut out = vec![0.0f32; rows * n];
        let mut stats = GateStats::default();
        gated_packed_rows_range_width::<1>(&pack, 0, rows, &cols, &mut out, &mut stats);
        variants.push(("width1", out.clone(), stats));
        stats = GateStats::default();
        gated_packed_rows_range_width::<4>(&pack, 0, rows, &cols, &mut out, &mut stats);
        variants.push(("width4", out.clone(), stats));
        stats = GateStats::default();
        gated_packed_rows_range_width::<8>(&pack, 0, rows, &cols, &mut out, &mut stats);
        variants.push(("width8", out.clone(), stats));
        for (name, strat) in [
            ("lane", KernelStrategy::Lane),
            ("tile_skip", KernelStrategy::TileSkip),
            ("event_list", KernelStrategy::EventList),
        ] {
            stats = GateStats::default();
            gated_packed_rows_strategy(&pack, 0, rows, &cols, &mut out, &mut stats, strat);
            variants.push((name, out.clone(), stats));
        }

        let x_nonzero = a.iter().filter(|&&v| v != 0.0).count() as u64;
        for (name, o, s) in &variants {
            let ctx = format!("N={n_space} rows={rows} m={m} n={n} {name}");
            if o != &want {
                return Err(format!("{ctx}: output != scalar oracle"));
            }
            if s.xnor + s.resting() != s.total {
                return Err(format!("{ctx}: xnor + resting != total"));
            }
            if s.total != (rows * m * n) as u64 || s.evals != (rows * n) as u64 {
                return Err(format!("{ctx}: total/evals miscounted"));
            }
            if s.x_count != (rows * m) as u64 || s.x_nonzero != x_nonzero {
                return Err(format!("{ctx}: activation tallies miscounted"));
            }
            if s.occ_hist.iter().sum::<u64>() != rows as u64 {
                return Err(format!("{ctx}: occupancy histogram lost rows"));
            }
            if s != &variants[0].2 {
                return Err(format!("{ctx}: stats diverge from width1"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// evaluate_engine coverage (no artifacts needed)
// ---------------------------------------------------------------------------

/// A backend that always predicts class 0 — lets us pin the accuracy
/// *denominator*: it must be the true dataset length, including the final
/// partial batch that the old eval loop silently dropped.
struct ConstPredictor {
    batch: usize,
    n_classes: usize,
    logits: Vec<f32>,
}

impl ExecEngine for ConstPredictor {
    fn name(&self) -> &'static str {
        "const"
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn n_classes(&self) -> usize {
        self.n_classes
    }
    fn infer_batch(&mut self, _x: &[f32]) -> anyhow::Result<&[f32]> {
        Ok(&self.logits)
    }
}

#[test]
fn evaluate_covers_full_split_including_remainder() {
    let len = 43usize; // 43 % 16 = 11: the old loop scored only 32 samples
    let batch = 16usize;
    let ds = data::open("synth_mnist", false, len).unwrap();
    let mut logits = vec![0.0f32; batch * 10];
    for b in 0..batch {
        logits[b * 10] = 1.0; // always class 0
    }
    let mut eng = ConstPredictor { batch, n_classes: 10, logits };
    let acc = evaluate_engine(&mut eng, ds.as_ref()).unwrap();
    // exact expectation over the *whole* split
    let mut buf = vec![0.0f32; ds.sample_len()];
    let zeros = (0..len).filter(|&i| ds.fill(i, &mut buf) == 0).count();
    let want = zeros as f64 / len as f64;
    assert!(
        (acc - want).abs() < 1e-12,
        "accuracy {acc} != {want}: denominator is not the dataset length"
    );
}

// ---------------------------------------------------------------------------
// Native engine over every Table 1 method (no artifacts needed)
// ---------------------------------------------------------------------------

fn tiny_mlp_model(space: Option<DiscreteSpace>, seed: u64) -> ModelState {
    let d = |name: &str, shape: Vec<usize>, kind, layer| ParamDesc {
        name: name.into(),
        shape,
        kind,
        layer,
    };
    use ParamKind::*;
    let mut m = init_model(
        vec![
            d("W0", vec![784, 24], Weight, 0),
            d("gamma0", vec![24], Gamma, 0),
            d("beta0", vec![24], Beta, 0),
            d("W1", vec![24, 24], Weight, 1),
            d("gamma1", vec![24], Gamma, 1),
            d("beta1", vec![24], Beta, 1),
            d("W2", vec![24, 10], Weight, 2),
        ],
        vec!["rmean0".into(), "rvar0".into(), "rmean1".into(), "rvar1".into()],
        &[24, 24, 24, 24],
        space.unwrap_or(DiscreteSpace::TERNARY),
        seed,
    );
    if space.is_none() {
        // fp baseline: dense weights, mirroring Trainer::new
        use gxnor::nn::params::ParamValue;
        use gxnor::util::prng::Prng;
        let mut rng = Prng::new(seed ^ 0xF9);
        for (dsc, v) in m.descs.iter().zip(m.values.iter_mut()) {
            if dsc.kind == Weight {
                let fan_in: usize =
                    dsc.shape[..dsc.shape.len() - 1].iter().product::<usize>().max(1);
                let std = (2.0 / fan_in as f32).sqrt();
                *v = ParamValue::Dense(
                    (0..dsc.numel()).map(|_| rng.normal_f32() * std).collect(),
                );
            }
        }
    }
    m
}

#[test]
fn native_engine_runs_every_method() {
    let methods = [Method::Gxnor, Method::Bnn, Method::Bwn, Method::Twn, Method::Fp];
    let ds = data::open("synth_mnist", false, 37).unwrap();
    for method in methods {
        let model = tiny_mlp_model(method.weight_space(), 9);
        let mut eng = NativeEngine::from_model("mlp", method, &model, 0.5, 8, 10, 1).unwrap();
        let acc = evaluate_engine(&mut eng, ds.as_ref()).unwrap();
        assert!((0.0..=1.0).contains(&acc), "{}: acc {acc}", method.name());
        // packed path fires exactly for the packed-activation methods
        let expect_packed = matches!(method, Method::Gxnor | Method::Bnn);
        assert_eq!(eng.has_packed_layers(), expect_packed, "{}", method.name());
        if expect_packed {
            for rep in eng.gate_report() {
                let s = &rep.stats;
                assert_eq!(s.xnor + s.resting(), s.total, "{}", rep.name);
                assert!(
                    gate_rate_matches(s.resting_rate(), rep.w_zero_fraction, s.x_zero_fraction(), 0.02),
                    "{} {}: measured {:.4} vs analytic {:.4}",
                    method.name(),
                    rep.name,
                    s.resting_rate(),
                    gxnor_resting_probability(rep.w_zero_fraction, s.x_zero_fraction())
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Threaded-vs-single-thread parity (no artifacts needed)
// ---------------------------------------------------------------------------

/// Thread counts the parity suite sweeps: 1 (the serial reference), 2,
/// and 7 (coprime with typical batch sizes, so shards end ragged). CI
/// adds one more via `GXNOR_THREADS` (the workflow exports 3) to exercise
/// a shard boundary no local run used.
fn parity_thread_counts() -> Vec<usize> {
    let mut counts = vec![1usize, 2, 7];
    if let Some(n) = std::env::var("GXNOR_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        if !counts.contains(&n) {
            counts.push(n);
        }
    }
    counts
}

/// Sharding `infer_batch` across workers must be invisible: logits and
/// the merged per-layer / total `GateStats` are bit-identical for every
/// thread count, for every Table 1 method, including batches the thread
/// count does not divide and thread counts exceeding the batch.
#[test]
fn prop_threaded_infer_batch_is_bit_identical() {
    let methods = [Method::Gxnor, Method::Bnn, Method::Bwn, Method::Twn, Method::Fp];
    property("threaded infer parity", 10, |g: &mut Gen| {
        let method = *g.choose(&methods);
        let batch = g.usize_in(1, 14);
        let seed = g.u64();
        let model = tiny_mlp_model(method.weight_space(), seed);
        let x = g.vec_f32(batch * 784, -1.0, 1.0);
        let mut runs: Vec<(usize, Vec<f32>, Vec<GateStats>, GateStats)> = Vec::new();
        for threads in parity_thread_counts() {
            let mut eng = NativeEngine::from_model("mlp", method, &model, 0.5, batch, 10, threads)
                .map_err(|e| e.to_string())?;
            // two calls: tallies must also merge exactly across calls
            eng.infer_batch(&x).map_err(|e| e.to_string())?;
            let logits = eng.infer_batch(&x).map_err(|e| e.to_string())?.to_vec();
            let stats: Vec<GateStats> = eng.gate_report().iter().map(|r| r.stats).collect();
            runs.push((threads, logits, stats, eng.total_gate_stats()));
        }
        let (_, wl, ws, wt) = &runs[0];
        for (threads, logits, stats, total) in &runs[1..] {
            if logits != wl {
                return Err(format!(
                    "{} batch={batch} threads={threads}: logits diverge",
                    method.name()
                ));
            }
            if stats != ws || total != wt {
                return Err(format!(
                    "{} batch={batch} threads={threads}: gate stats diverge",
                    method.name()
                ));
            }
        }
        Ok(())
    });
}

/// Same invariant through the full evaluation loop (prefetched batches,
/// padded final batch): accuracy and merged GateStats must not depend on
/// the engine's thread count.
#[test]
fn evaluate_engine_is_thread_count_invariant() {
    let ds = data::open("synth_mnist", false, 43).unwrap(); // 43 % 8 != 0
    let model = tiny_mlp_model(Some(DiscreteSpace::TERNARY), 17);
    let mut want: Option<(f64, GateStats)> = None;
    for threads in parity_thread_counts() {
        let mut eng =
            NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 8, 10, threads).unwrap();
        let acc = evaluate_engine(&mut eng, ds.as_ref()).unwrap();
        let total = eng.total_gate_stats();
        if let Some((wa, wt)) = want {
            assert_eq!(acc, wa, "threads={threads}: accuracy diverges");
            assert_eq!(total, wt, "threads={threads}: merged stats diverge");
        } else {
            want = Some((acc, total));
        }
    }
}

/// The serving path `gxnor eval --engine native` rides: manifest metadata
/// plus a checkpoint, no PJRT client, no lowered HLO files on disk.
#[test]
fn native_engine_from_checkpoint_is_device_free() {
    const MANIFEST: &str = r#"{
      "format": 1,
      "graphs": {
        "mlp_multi_b16_infer": {
          "file": "mlp_multi_b16_infer.hlo.txt",
          "arch": "mlp", "mode": "multi", "batch": 16, "width": 1.0,
          "kind": "infer", "input_shape": [784], "n_classes": 10,
          "params": [
            {"name": "W0", "shape": [784, 24], "kind": "weight", "layer": 0},
            {"name": "gamma0", "shape": [24], "kind": "gamma", "layer": 0},
            {"name": "beta0", "shape": [24], "kind": "beta", "layer": 0},
            {"name": "W1", "shape": [24, 24], "kind": "weight", "layer": 1},
            {"name": "gamma1", "shape": [24], "kind": "gamma", "layer": 1},
            {"name": "beta1", "shape": [24], "kind": "beta", "layer": 1},
            {"name": "W2", "shape": [24, 10], "kind": "weight", "layer": 2}
          ],
          "bn_state": [
            {"name": "rmean0", "shape": [24]},
            {"name": "rvar0", "shape": [24]},
            {"name": "rmean1", "shape": [24]},
            {"name": "rvar1", "shape": [24]}
          ],
          "inputs": [],
          "outputs": []
        }
      }
    }"#;
    let m = Manifest::parse("/tmp/none", MANIFEST).unwrap();
    let model = tiny_mlp_model(Some(DiscreteSpace::TERNARY), 31);
    let tmp = std::env::temp_dir().join(format!("gxnor_devfree_{}.ckpt", std::process::id()));
    let tmp_s = tmp.to_str().unwrap().to_string();
    checkpoint::save(&model, &tmp_s).unwrap();

    let mut eng =
        gxnor::engine::native_engine_from_checkpoint(&m, "mlp", Method::Gxnor, 0.5, &tmp_s, 1)
            .unwrap();
    assert_eq!(eng.batch(), 16);
    assert_eq!(eng.n_classes(), 10);
    let ds = data::open("synth_mnist", false, 50).unwrap();
    let acc = evaluate_engine(&mut eng, ds.as_ref()).unwrap();
    // identical weights through the direct constructor: same accuracy
    let mut direct =
        NativeEngine::from_model("mlp", Method::Gxnor, &model, 0.5, 16, 10, 1).unwrap();
    let acc_direct = evaluate_engine(&mut direct, ds.as_ref()).unwrap();
    assert_eq!(acc, acc_direct);
    // unknown arch/mode is a clean error, not a panic
    assert!(gxnor::engine::native_engine_from_checkpoint(
        &m,
        "cnn_mnist",
        Method::Gxnor,
        0.5,
        &tmp_s,
        1
    )
    .is_err());
    std::fs::remove_file(&tmp).unwrap();
}

// ---------------------------------------------------------------------------
// XLA vs native parity (artifact-gated)
// ---------------------------------------------------------------------------

fn manifest() -> Option<Manifest> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some(Manifest::load("artifacts").unwrap())
    } else {
        eprintln!("skipping engine parity: run `make artifacts`");
        None
    }
}

/// Prefer cheap b16 graphs where available.
fn b16_manifest(m: &Manifest) -> Manifest {
    let mut m2 = m.clone();
    m2.graphs.retain(|g| g.batch == 16 || g.mode != "multi");
    m2
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

/// Checkpoint round-trip, then batch-by-batch: native logits within 1e-4
/// (relative) of the XLA infer graph and argmax identical, for every
/// Table 1 method on every arch the manifest carries.
#[test]
fn native_matches_xla_on_same_checkpoint() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping engine parity: no PJRT client ({e})");
            return;
        }
    };
    let tmp = std::env::temp_dir().join(format!("gxnor_parity_{}.ckpt", std::process::id()));
    let tmp_s = tmp.to_str().unwrap().to_string();
    for arch in ["mlp", "cnn_mnist", "cnn_cifar"] {
        let dataset = if arch == "cnn_cifar" { "synth_cifar" } else { "synth_mnist" };
        for method in [Method::Gxnor, Method::Bnn, Method::Bwn, Method::Twn, Method::Fp] {
            let cfg = TrainConfig {
                arch: arch.into(),
                method,
                dataset: dataset.into(),
                train_len: 320,
                test_len: 160,
                epochs: if arch == "mlp" { 1 } else { 0 },
                seed: 13,
                verbose: false,
                ..Default::default()
            };
            let mut tr = match Trainer::new(&mut rt, &m, cfg.clone()) {
                Ok(t) => t,
                Err(_) => {
                    eprintln!("parity: no {arch} graphs in manifest, skipping");
                    continue;
                }
            };
            if cfg.epochs > 0 {
                let train = data::open(&cfg.dataset, true, cfg.train_len).unwrap();
                let test = data::open(&cfg.dataset, false, cfg.test_len).unwrap();
                tr.run(train.as_ref(), test.as_ref()).unwrap();
            }
            // checkpoint round-trip into a fresh trainer
            checkpoint::save(&tr.model, &tmp_s).unwrap();
            let mut tr2 = Trainer::new(&mut rt, &m, cfg.clone()).unwrap();
            checkpoint::load(&mut tr2.model, &tmp_s).unwrap();

            let test = data::open(&cfg.dataset, false, cfg.test_len).unwrap();
            let mut nat = tr2.native_engine().unwrap();
            let b = nat.batch();
            let sl = test.sample_len();
            let nc = nat.n_classes();
            let mut xla = tr2.xla_engine().unwrap();
            let mut x = vec![0.0f32; b * sl];
            for nb in 0..3 {
                for i in 0..b {
                    let idx = (nb * b + i) % test.len();
                    test.fill(idx, &mut x[i * sl..(i + 1) * sl]);
                }
                let lx = xla.infer_batch(&x).unwrap().to_vec();
                let ln = nat.infer_batch(&x).unwrap();
                for row in 0..b {
                    let rx = &lx[row * nc..(row + 1) * nc];
                    let rn = &ln[row * nc..(row + 1) * nc];
                    for k in 0..nc {
                        assert!(
                            rel_close(rx[k], rn[k], 1e-4),
                            "{arch}/{}: logit[{row},{k}] xla {} vs native {}",
                            method.name(),
                            rx[k],
                            rn[k]
                        );
                    }
                    // argmax must agree except on genuine near-ties, where
                    // f32-vs-f64 accumulation order may legitimately pick
                    // either class (the logits already matched above)
                    let mut sorted = rn.to_vec();
                    sorted.sort_by(|a, b| a.total_cmp(b));
                    let margin = sorted[nc - 1] - sorted[nc - 2];
                    if margin > 1e-3 * sorted[nc - 1].abs().max(1.0) {
                        assert_eq!(
                            gxnor::util::argmax(rx),
                            gxnor::util::argmax(rn),
                            "{arch}/{}: argmax diverges on row {row}",
                            method.name()
                        );
                    }
                }
            }
            // whole-split accuracy through the shared evaluator must agree
            // up to near-tie rows (a couple of samples at most)
            let acc_x = evaluate_engine(&mut xla, test.as_ref()).unwrap();
            drop(xla);
            let acc_n = evaluate_engine(&mut nat, test.as_ref()).unwrap();
            assert!(
                (acc_x - acc_n).abs() <= 2.0 / cfg.test_len as f64 + 1e-12,
                "{arch}/{}: accuracies diverge: xla {acc_x} vs native {acc_n}",
                method.name()
            );
        }
    }
    let _ = std::fs::remove_file(&tmp);
}

/// The gated-op rates the native engine measures on a *trained* gxnor
/// model must agree with the hwsim's Table 2 analytic prediction (computed
/// from the model's measured zero fractions) within 2%.
#[test]
fn trained_model_gate_rates_match_hwsim() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping gate-rate check: no PJRT client ({e})");
            return;
        }
    };
    let cfg = TrainConfig {
        arch: "mlp".into(),
        method: Method::Gxnor,
        dataset: "synth_mnist".into(),
        train_len: 600,
        test_len: 200,
        epochs: 2,
        seed: 7,
        verbose: false,
        ..Default::default()
    };
    let train = data::open(&cfg.dataset, true, cfg.train_len).unwrap();
    let test = data::open(&cfg.dataset, false, cfg.test_len).unwrap();
    let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
    tr.run(train.as_ref(), test.as_ref()).unwrap();
    let mut nat = tr.native_engine().unwrap();
    evaluate_engine(&mut nat, test.as_ref()).unwrap();
    let reps = nat.gate_report();
    assert!(!reps.is_empty(), "gxnor mlp must run gated layers");
    for rep in reps {
        let s = &rep.stats;
        assert!(
            gate_rate_matches(s.resting_rate(), rep.w_zero_fraction, s.x_zero_fraction(), 0.02),
            "{}: measured {:.4} vs analytic {:.4} (w0 {:.3}, x0 {:.3})",
            rep.name,
            s.resting_rate(),
            gxnor_resting_probability(rep.w_zero_fraction, s.x_zero_fraction()),
            rep.w_zero_fraction,
            s.x_zero_fraction()
        );
    }
}
