//! Native DST training engine: backward-kernel properties, gradient
//! correctness (finite differences on the smooth fp path), thread-count
//! determinism, repack-skip accounting, pad-row masking, memory claims,
//! and the artifact-gated XLA parity.
//!
//! Everything except the last section runs device-free. Thread sweeps
//! cover {1, 2, 7} plus `GXNOR_THREADS` (CI exports 3).

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{NativeTrainer, TrainConfig};
use gxnor::engine::backward::{
    accum_dw_packed, accum_dw_scalar, f32_rows_times_tern_cols, f32_rows_times_tern_cols_oracle,
};
use gxnor::engine::bitplane::{BitplaneCols, PackScratch};
use gxnor::engine::NativeTrainEngine;
use gxnor::nn::init::init_model;
use gxnor::nn::params::{ModelState, ParamDesc, ParamKind, ParamValue};
use gxnor::ptest::{property, Gen};
use gxnor::ternary::{DiscreteSpace, DstStats};
use gxnor::util::prng::Prng;

// ---------------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------------

fn d(name: &str, shape: Vec<usize>, kind: ParamKind, layer: usize) -> ParamDesc {
    ParamDesc { name: name.into(), shape, kind, layer }
}

/// Narrow MLP (784-H-H-10) descriptors in graph order.
fn mlp_descs(hidden: usize) -> (Vec<ParamDesc>, Vec<String>, Vec<usize>) {
    use ParamKind::*;
    (
        vec![
            d("W0", vec![784, hidden], Weight, 0),
            d("gamma0", vec![hidden], Gamma, 0),
            d("beta0", vec![hidden], Beta, 0),
            d("W1", vec![hidden, hidden], Weight, 1),
            d("gamma1", vec![hidden], Gamma, 1),
            d("beta1", vec![hidden], Beta, 1),
            d("W2", vec![hidden, 10], Weight, 2),
        ],
        vec!["rmean0".into(), "rvar0".into(), "rmean1".into(), "rvar1".into()],
        vec![hidden, hidden, hidden, hidden],
    )
}

/// Narrow cnn_mnist (cC5-MP2-cC5-MP2-fcFC-10) descriptors.
fn cnn_descs(c: usize, fc: usize) -> (Vec<ParamDesc>, Vec<String>, Vec<usize>) {
    use ParamKind::*;
    let flat = 4 * 4 * c;
    (
        vec![
            d("W0", vec![5, 5, 1, c], Weight, 0),
            d("gamma0", vec![c], Gamma, 0),
            d("beta0", vec![c], Beta, 0),
            d("W1", vec![5, 5, c, c], Weight, 1),
            d("gamma1", vec![c], Gamma, 1),
            d("beta1", vec![c], Beta, 1),
            d("W2", vec![flat, fc], Weight, 2),
            d("gamma2", vec![fc], Gamma, 2),
            d("beta2", vec![fc], Beta, 2),
            d("W3", vec![fc, 10], Weight, 3),
        ],
        vec![
            "rmean0".into(),
            "rvar0".into(),
            "rmean1".into(),
            "rvar1".into(),
            "rmean2".into(),
            "rvar2".into(),
        ],
        vec![c, c, c, c, fc, fc],
    )
}

/// Model with fp (dense Glorot) weights for the differentiable FD checks.
fn fp_model(descs: Vec<ParamDesc>, bn_names: Vec<String>, bn_lens: &[usize], seed: u64) -> ModelState {
    let mut m = init_model(descs, bn_names, bn_lens, DiscreteSpace::TERNARY, seed);
    let mut rng = Prng::new(seed ^ 0xF9);
    for (dsc, v) in m.descs.iter().zip(m.values.iter_mut()) {
        if dsc.kind == ParamKind::Weight {
            let fan_in: usize = dsc.shape[..dsc.shape.len() - 1].iter().product::<usize>().max(1);
            let std = (2.0 / fan_in as f32).sqrt();
            *v = ParamValue::Dense((0..dsc.numel()).map(|_| rng.normal_f32() * std).collect());
        }
    }
    m
}

fn random_batch(batch: usize, len: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Prng::new(seed);
    let x = (0..batch * len).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let y = (0..batch).map(|_| rng.below(10) as i32).collect();
    (x, y)
}

/// Thread counts the determinism suite sweeps; CI adds GXNOR_THREADS=3.
fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1usize, 2, 7];
    if let Some(n) = std::env::var("GXNOR_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        if !counts.contains(&n) {
            counts.push(n);
        }
    }
    counts
}

fn base_cfg(method: Method, threads: usize, seed: u64) -> TrainConfig {
    TrainConfig { method, threads, seed, verbose: false, ..Default::default() }
}

// ---------------------------------------------------------------------------
// Backward-kernel properties (satellite: vs f64 oracle, all spaces,
// ragged shapes, sharded word ranges)
// ---------------------------------------------------------------------------

/// Both backward GEMM kernels vs their gated f64 oracles, **exact**
/// equality: the f32 operand mixes grid values from every `DiscreteSpace`
/// with free normals (multi-level activations and raw cotangents), the
/// ternary operand is a random sign/zero pattern, shapes are ragged
/// (straddling u64 word edges), and the `dW` kernel additionally runs
/// split into {1, 2, 7} word-range shards — all must agree bit for bit.
#[test]
fn prop_backward_gemms_match_f64_oracle() {
    property("backward gemms vs f64 oracle", 80, |g: &mut Gen| {
        let n_space = g.usize_in(0, 7) as u32;
        let space = DiscreteSpace::new(n_space);
        let rows = g.usize_in(1, 6);
        let k = g.usize_in(1, 200); // ternary-lane count: straddles words
        let n = g.usize_in(1, 18);
        let from_grid = g.bool();
        let mut f32_val = |g: &mut Gen| {
            if from_grid {
                space.state(g.usize_in(0, space.n_states()))
            } else {
                g.normal_f32()
            }
        };
        let tern = |g: &mut Gen| g.usize_in(0, 3) as f32 - 1.0;

        // dX-shaped kernel: f32 rows × packed ternary columns
        let a: Vec<f32> = (0..rows * k).map(|_| f32_val(g)).collect();
        let t: Vec<f32> = (0..k * n).map(|_| tern(g)).collect();
        let planes = BitplaneCols::pack_cols(&t, k, n);
        let mut got = vec![0.0f32; rows * n];
        let mut want = vec![0.0f32; rows * n];
        f32_rows_times_tern_cols(&a, rows, &planes, &mut got);
        f32_rows_times_tern_cols_oracle(&a, rows, &t, k, n, &mut want);
        if got != want {
            return Err(format!("N={n_space} rows={rows} k={k} n={n}: dX kernel != oracle"));
        }

        // dW-shaped kernel: packed ternary rows × f32 cotangent rows
        let xt: Vec<f32> = (0..rows * k).map(|_| tern(g)).collect();
        let dy: Vec<f32> = (0..rows * n).map(|_| f32_val(g)).collect();
        let mut pack = PackScratch::new();
        pack.pack_rows(&xt, rows, k);
        let words = pack.words();
        let mut oracle = vec![0.0f64; k * n];
        accum_dw_scalar(&xt, rows, k, &dy, n, 0, k, &mut oracle);
        for shards in [1usize, 2, 7] {
            let mut got = vec![0.0f64; k * n];
            let per = words.div_ceil(shards).max(1);
            let mut w0 = 0usize;
            while w0 < words {
                let w1 = (w0 + per).min(words);
                // `words` is the lane-padded stride: shards past the
                // logical fan-in clamp to empty slices (no gate bits there)
                let lane_lo = (w0 * 64).min(k);
                let lane_hi = (w1 * 64).min(k);
                accum_dw_packed(
                    &pack,
                    rows,
                    &dy,
                    n,
                    w0,
                    w1,
                    &mut got[lane_lo * n..lane_hi * n],
                );
                w0 = w1;
            }
            if got != oracle {
                return Err(format!(
                    "N={n_space} rows={rows} k={k} n={n} shards={shards}: dW kernel != oracle"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Gradient correctness: finite differences on the smooth fp path
// ---------------------------------------------------------------------------

/// Central-difference check of every analytic gradient the engine emits
/// (weights, gamma, beta) on the **fp** configuration, whose loss is a
/// smooth function of the parameters (identity activations; L2 hinge and
/// train-mode BN are differentiable a.e.). This pins the whole backward
/// composition — loss grad, BN backward incl. batch statistics, GEMM
/// transposes, im2col/col2im, pool routing — against the forward pass
/// itself, with no reference implementation in the loop.
fn fd_check(
    arch: &str,
    descs: Vec<ParamDesc>,
    bn_names: Vec<String>,
    bn_lens: &[usize],
    batch: usize,
    sample_len: usize,
    seed: u64,
) {
    let mut model = fp_model(descs, bn_names, bn_lens, seed);
    let mut eng =
        NativeTrainEngine::new(arch, Method::Fp, &model.descs, batch, 10, 0.5, 0.5, 2).unwrap();
    let (x, y) = random_batch(batch, sample_len, seed ^ 0xAB);
    let n_params = model.descs.len();
    let mut dirty = vec![true; n_params];
    let outs = eng.step(&x, &y, batch, &model, &mut dirty).unwrap();
    let grads: Vec<Vec<f32>> = outs[3..3 + n_params].to_vec();

    let eps = 1e-2f64;
    let mut rng = Prng::new(seed ^ 0x51);
    let mut checked = 0usize;
    for pi in 0..n_params {
        let numel = model.descs[pi].numel();
        for _ in 0..8.min(numel) {
            let j = rng.below(numel);
            let orig = match &model.values[pi] {
                ParamValue::Dense(v) => v[j],
                _ => unreachable!("fp model is all-dense"),
            };
            let mut loss_at = |val: f32,
                               model: &mut ModelState,
                               eng: &mut NativeTrainEngine|
             -> f64 {
                if let ParamValue::Dense(v) = &mut model.values[pi] {
                    v[j] = val;
                }
                let mut dirty = vec![false; n_params];
                let o = eng.step(&x, &y, batch, model, &mut dirty).unwrap();
                o[0][0] as f64
            };
            let lp = loss_at((orig as f64 + eps) as f32, &mut model, &mut eng);
            let lm = loss_at((orig as f64 - eps) as f32, &mut model, &mut eng);
            if let ParamValue::Dense(v) = &mut model.values[pi] {
                v[j] = orig;
            }
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads[pi][j] as f64;
            // loose enough to absorb f32 loss rounding and the rare
            // hinge/pool kink inside the FD window, far tighter than any
            // structural bug (sign, transpose, scaling) would produce
            let tol = 3e-2 * fd.abs().max(an.abs()) + 5e-3;
            assert!(
                (fd - an).abs() <= tol,
                "{arch} param {pi} ({}) elem {j}: analytic {an:.6} vs FD {fd:.6}",
                model.descs[pi].name
            );
            checked += 1;
        }
    }
    assert!(checked >= 3 * n_params.min(8), "FD check exercised too few elements");
}

#[test]
fn fd_gradients_mlp() {
    let (descs, names, lens) = mlp_descs(16);
    fd_check("mlp", descs, names, &lens, 8, 784, 11);
}

#[test]
fn fd_gradients_cnn() {
    let (descs, names, lens) = cnn_descs(6, 8);
    fd_check("cnn_mnist", descs, names, &lens, 3, 28 * 28, 23);
}

// ---------------------------------------------------------------------------
// Thread-count determinism: the acceptance criterion, measured
// ---------------------------------------------------------------------------

/// N native training steps must be **bit-identical** for every thread
/// count — per-step loss/acc/sparsity/DST statistics and the final
/// packed model — for the packed-activation methods on both topologies.
#[test]
fn training_is_bit_identical_across_thread_counts() {
    let cases: [(&str, Method); 4] = [
        ("mlp", Method::Gxnor),
        ("mlp", Method::Bnn),
        ("mlp", Method::Multi { n1: 2, n2: 2 }),
        ("cnn_mnist", Method::Gxnor),
    ];
    for (arch, method) in cases {
        let (descs, names, lens) = if arch == "mlp" {
            mlp_descs(24)
        } else {
            cnn_descs(8, 8)
        };
        let sample_len = if arch == "mlp" { 784 } else { 28 * 28 };
        let batch = 9; // coprime with every swept thread count
        let (x, y) = random_batch(batch, sample_len, 77);
        let steps = 3usize;
        let mut want: Option<(Vec<(f64, f64, f64)>, Vec<DstStats>, Vec<u8>)> = None;
        for threads in thread_counts() {
            let mut cfg = base_cfg(method, threads, 5);
            cfg.arch = arch.into();
            let mut tr =
                NativeTrainer::from_descs(cfg, descs.clone(), names.clone(), &lens, batch, 10)
                    .unwrap();
            let mut stats = Vec::new();
            let mut dsts = Vec::new();
            for _ in 0..steps {
                let s = tr.step(&x, &y, batch, 0.05).unwrap();
                stats.push((s.loss, s.acc, s.sparsity));
                dsts.push(s.dst);
            }
            let fp = tr.model.fingerprint();
            match &want {
                None => want = Some((stats, dsts, fp)),
                Some((ws, wd, wf)) => {
                    assert_eq!(&stats, ws, "{arch}/{:?} threads={threads}: stats diverge", method);
                    assert_eq!(&dsts, wd, "{arch}/{:?} threads={threads}: DST diverges", method);
                    assert_eq!(&fp, wf, "{arch}/{:?} threads={threads}: model diverges", method);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Repack-skip accounting (satellite: repacks ≤ transitioned updates)
// ---------------------------------------------------------------------------

#[test]
fn bitplanes_repack_at_most_once_per_transitioned_update() {
    let (descs, names, lens) = mlp_descs(16);
    let cfg = base_cfg(Method::Gxnor, 2, 3);
    let mut tr = NativeTrainer::from_descs(cfg, descs, names, &lens, 8, 10).unwrap();
    let (x, y) = random_batch(8, 784, 4);

    // lr = 0: increments are exactly zero, DST can never transition, and
    // therefore no repack may happen beyond the initial packs
    for _ in 0..3 {
        tr.step(&x, &y, 8, 0.0).unwrap();
    }
    assert_eq!(tr.dst_update_count(), 9, "3 steps × 3 discrete tensors");
    assert_eq!(tr.transitioned_update_count(), 0);
    assert_eq!(tr.repack_count(), 0, "zero-transition steps must not repack");

    // real steps: repacks may happen, but never more than the number of
    // update events that actually moved a state
    for _ in 0..4 {
        tr.step(&x, &y, 8, 0.1).unwrap();
    }
    assert_eq!(tr.dst_update_count(), 21);
    assert!(
        tr.repack_count() <= tr.transitioned_update_count(),
        "repacks {} > transitioned updates {}",
        tr.repack_count(),
        tr.transitioned_update_count()
    );
    assert!(tr.engine_bitplane_bytes() > 0);
}

// ---------------------------------------------------------------------------
// Pad-row masking (satellite: padded final batch masks gradients)
// ---------------------------------------------------------------------------

/// A padded batch must train exactly like its valid rows: (a) the pad
/// rows' contents are irrelevant, and (b) the step equals a trainer whose
/// batch size *is* the valid count — i.e. a padded partial final batch
/// trains identically to running that partial batch at its natural size
/// (the drop-last epoch plus one correctly-masked extra step).
#[test]
fn padded_rows_are_fully_masked() {
    let (descs, names, lens) = mlp_descs(16);
    let valid = 5usize;
    let (xv, yv) = random_batch(valid, 784, 91);

    let run_padded = |pad_fill: f32, pad_label: i32| {
        let cfg = base_cfg(Method::Gxnor, 2, 13);
        let mut tr =
            NativeTrainer::from_descs(cfg, descs.clone(), names.clone(), &lens, 8, 10).unwrap();
        let mut x = vec![pad_fill; 8 * 784];
        let mut y = vec![pad_label; 8];
        x[..valid * 784].copy_from_slice(&xv);
        y[..valid].copy_from_slice(&yv);
        let s = tr.step(&x, &y, valid, 0.05).unwrap();
        (s.loss, s.acc, s.dst, tr.model.fingerprint())
    };
    let a = run_padded(0.25, 1);
    let b = run_padded(-0.9, 7);
    assert_eq!(a, b, "pad-row contents leaked into the step");

    // equivalence with a natural batch of `valid` samples
    let cfg = base_cfg(Method::Gxnor, 2, 13);
    let mut tr =
        NativeTrainer::from_descs(cfg, descs.clone(), names.clone(), &lens, valid, 10).unwrap();
    let s = tr.step(&xv, &yv, valid, 0.05).unwrap();
    assert_eq!((s.loss, s.acc, s.dst, tr.model.fingerprint()), a);
}

/// Full-run regression: a train split that does not divide the batch
/// completes with the padded prefetcher and performs ceil(len/batch)
/// steps per epoch — every sample contributes, none twice.
#[test]
fn padded_epoch_covers_every_sample() {
    let (descs, names, lens) = mlp_descs(16);
    let mut cfg = base_cfg(Method::Gxnor, 2, 21);
    cfg.train_len = 40; // 40 = 2×16 + 8: one padded partial batch
    cfg.test_len = 24;
    cfg.epochs = 2;
    let mut tr = NativeTrainer::from_descs(cfg, descs, names, &lens, 16, 10).unwrap();
    let train = gxnor::data::open("synth_mnist", true, 40).unwrap();
    let test = gxnor::data::open("synth_mnist", false, 24).unwrap();
    let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
    // 3 steps per epoch (16 + 16 + 8-padded), 2 epochs
    assert_eq!(report.recorder.len("loss"), 6);
    assert_eq!(report.recorder.len("test_acc"), 2);
}

// ---------------------------------------------------------------------------
// Memory accounting (satellite: the hidden-weight-free claim, numerically)
// ---------------------------------------------------------------------------

#[test]
fn native_training_holds_no_f32_weight_buffers() {
    let (descs, names, lens) = mlp_descs(24);
    let mut cfg = base_cfg(Method::Gxnor, 0, 7);
    cfg.train_len = 64;
    cfg.test_len = 32;
    cfg.epochs = 1;
    let mut tr = NativeTrainer::from_descs(cfg, descs, names, &lens, 16, 10).unwrap();
    let train = gxnor::data::open("synth_mnist", true, 64).unwrap();
    let test = gxnor::data::open("synth_mnist", false, 32).unwrap();
    let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
    // the paper's Remark 2, asserted numerically: no fp masters, no f32
    // mirrors, and the packed store is >10x smaller than f32 would be
    assert_eq!(report.hidden_fp32_bytes, 0);
    assert_eq!(report.weight_f32_mirror_bytes, 0);
    assert!(report.packed_bytes * 10 < report.fp32_bytes);
    assert_eq!(report.marshal_time_ms, 0.0, "there is no boundary to marshal across");
    // derived bitplanes are bit-sized too: 2 plane bits per weight bit-pair,
    // twice (cols + rows) — far under the f32 expansion
    assert!(tr.engine_bitplane_bytes() < report.fp32_bytes / 4);
}

// ---------------------------------------------------------------------------
// End-to-end: native DST training actually learns
// ---------------------------------------------------------------------------

#[test]
fn native_gxnor_training_learns_synth_digits() {
    let (descs, names, lens) = mlp_descs(32);
    let mut cfg = base_cfg(Method::Gxnor, 0, 42);
    cfg.train_len = 600;
    cfg.test_len = 200;
    cfg.epochs = 3;
    let mut tr = NativeTrainer::from_descs(cfg, descs, names, &lens, 25, 10).unwrap();
    let train = gxnor::data::open("synth_mnist", true, 600).unwrap();
    let test = gxnor::data::open("synth_mnist", false, 200).unwrap();
    let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
    let losses = report.recorder.get("epoch_loss");
    assert_eq!(losses.len(), 3);
    assert!(
        losses[2] < losses[0],
        "loss did not decrease: {losses:?}"
    );
    assert!(
        report.test_acc > 0.15,
        "native DST training stuck at {:.1}% (chance is 10%)",
        100.0 * report.test_acc
    );
    // weights moved, stayed on the grid, and the trainer counted it
    assert!(tr.transitioned_update_count() > 0);
    assert!(tr.repack_count() <= tr.transitioned_update_count());
    assert!(report.weight_zero_fraction > 0.0 && report.weight_zero_fraction < 1.0);
}

/// Every method the native trainer supports — including the multi-level
/// `multi:N1,N2` spaces of Fig. 13, on the multi-bitplane kernels —
/// completes a short run with a finite loss and no f32 weight mirrors.
#[test]
fn native_trainer_method_coverage() {
    for method in [
        Method::Gxnor,
        Method::Bnn,
        Method::Twn,
        Method::Bwn,
        Method::Fp,
        Method::Multi { n1: 2, n2: 2 },
        Method::Multi { n1: 3, n2: 2 },
        Method::Multi { n1: 0, n2: 2 },
        Method::Multi { n1: 1, n2: 0 }, // hl = 0.5: single-window quant_bwd
        Method::Multi { n1: 6, n2: 4 },
    ] {
        let (descs, names, lens) = mlp_descs(16);
        let mut cfg = base_cfg(method, 2, 9);
        cfg.train_len = 48;
        cfg.test_len = 24;
        cfg.epochs = 1;
        if method == Method::Fp {
            cfg.lr_start = 5e-3;
            cfg.lr_fin = 5e-4;
        }
        let mut tr =
            NativeTrainer::from_descs(cfg, descs, names, &lens, 16, 10).unwrap();
        let train = gxnor::data::open("synth_mnist", true, 48).unwrap();
        let test = gxnor::data::open("synth_mnist", false, 24).unwrap();
        let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
        assert!(report.final_train_loss.is_finite(), "{:?}", method);
        assert!((0.0..=1.0).contains(&report.test_acc), "{:?}", method);
        // Remark 2 holds for every state count, not just ternary
        assert_eq!(report.weight_f32_mirror_bytes, 0, "{:?}", method);
        assert_eq!(report.hidden_fp32_bytes, 0, "{:?}", method);
    }
    // the hidden-weight baseline keeps fp masters — clean error, not a panic
    let (descs, names, lens) = mlp_descs(16);
    let mut cfg = base_cfg(Method::Gxnor, 1, 9);
    cfg.update_rule = gxnor::coordinator::UpdateRule::Hidden;
    assert!(NativeTrainer::from_descs(cfg, descs, names, &lens, 8, 10).is_err());
}

// ---------------------------------------------------------------------------
// XLA parity (artifact-gated)
// ---------------------------------------------------------------------------

/// N-step training parity under a shared seed: same manifest shapes, same
/// batches, same optimizer/DST streams. Loss curves must agree within
/// float-accumulation tolerance and the DST transition counts must be
/// identical step for step (same uniforms, same decisions).
#[test]
fn native_training_matches_xla_steps() {
    use gxnor::runtime::client::Runtime;
    use gxnor::runtime::manifest::Manifest;
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping native-vs-xla training parity: run `make artifacts`");
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    let mut rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping native-vs-xla training parity: no PJRT client ({e})");
            return;
        }
    };
    // prefer the cheap b16 graphs, like the inference parity suite
    let mut m16 = manifest.clone();
    m16.graphs.retain(|g| g.batch == 16 || g.mode != "multi");
    let cfg = TrainConfig {
        arch: "mlp".into(),
        method: Method::Gxnor,
        seed: 13,
        verbose: false,
        ..Default::default()
    };
    let mut xla = match gxnor::coordinator::Trainer::new(&mut rt, &m16, cfg.clone()) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("skipping: no mlp train graph ({e})");
            return;
        }
    };
    let mut native = NativeTrainer::new(Some(&m16), cfg).unwrap();
    assert_eq!(xla.batch_size(), native.batch_size(), "shared manifest batch");
    let b = xla.batch_size();
    let ds = gxnor::data::open("synth_mnist", true, 320).unwrap();
    let sl = ds.sample_len();
    let mut x = vec![0.0f32; b * sl];
    let mut y = vec![0i32; b];
    let lr = 5e-3;
    for step in 0..5 {
        for i in 0..b {
            let idx = (step * b + i) % ds.len();
            y[i] = ds.fill(idx, &mut x[i * sl..(i + 1) * sl]) as i32;
        }
        let sx = xla.step(&x, &y, lr).unwrap();
        let sn = native.step(&x, &y, b, lr).unwrap();
        let tol = 1e-3 * sx.loss.abs().max(1.0);
        assert!(
            (sx.loss - sn.loss).abs() <= tol,
            "step {step}: loss xla {} vs native {}",
            sx.loss,
            sn.loss
        );
        assert_eq!(
            sx.dst.transitions, sn.dst.transitions,
            "step {step}: DST transition counts diverge under the shared seed"
        );
        assert_eq!(sx.dst.n, sn.dst.n, "step {step}: DST population diverges");
    }
}
