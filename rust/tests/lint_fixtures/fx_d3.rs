//! D3 fixture: hash-ordered containers in accumulation paths. Linted
//! under the pseudo-path `rust/src/engine/fx_d3.rs`.

use std::collections::HashMap; // seed:D3

pub fn bad_sum(xs: &[(u32, f32)]) -> f32 {
    let mut m = HashMap::new(); // seed:D3
    for &(k, v) in xs {
        m.insert(k, v);
    }
    m.values().sum() // iteration order decides float addition order
}

pub fn bad_set(ids: &[u32]) -> usize {
    let s: std::collections::HashSet<u32> = ids.iter().copied().collect(); // seed:D3
    s.len()
}

pub fn good_ordered(xs: &[(u32, f32)]) -> f32 {
    let mut m = std::collections::BTreeMap::new();
    for &(k, v) in xs {
        m.insert(k, v);
    }
    m.values().sum()
}

#[cfg(test)]
mod tests {
    pub fn assertion_maps_are_exempt() {
        let _ = std::collections::HashMap::<u32, u32>::new();
    }
}
