//! D2 fixture: wall-clock reads inside virtual-clock code. Linted under
//! the pseudo-path `rust/src/serve/queue.rs`.

use std::time::Instant; // seed:D2

pub fn bad_now() -> u64 {
    let t0 = Instant::now(); // seed:D2
    t0.elapsed().as_nanos() as u64
}

pub fn bad_wall_clock() {
    let _ = std::time::SystemTime::now(); // seed:D2
}

pub fn good_virtual_clock(now_ns: u64, deadline_ns: u64) -> bool {
    now_ns >= deadline_ns
}
