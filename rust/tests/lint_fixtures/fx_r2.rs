//! R2 fixture: panics on serve request paths. Linted under the
//! pseudo-path `rust/src/serve/fx_r2.rs`.

pub fn bad_unwrap(v: Option<u32>) -> u32 {
    v.unwrap() // seed:R2
}

pub fn bad_expect(v: Option<u32>) -> u32 {
    v.expect("always some") // seed:R2
}

pub fn good_classified(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "empty request".to_string())
}

pub fn good_defaulted(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}
