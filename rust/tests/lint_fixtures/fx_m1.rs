//! M1 fixture: f32 weight mirrors in the step loop (Remark 2). Linted
//! under the pseudo-path `rust/src/coordinator/trainer.rs`.

pub fn bad_full_unpack(p: &PackedTensor) -> Vec<f32> {
    p.unpack() // seed:M1
}

pub fn bad_mirror_bindings(n: usize) {
    let mut w_f32 = vec![0f32; n]; // seed:M1
    let weight_mirror = make_buffer(n); // seed:M1
    w_f32.clear();
    drop(weight_mirror);
}

pub fn good_streaming(p: &PackedTensor, chunk: &mut [f32]) {
    // bounded per-chunk expansion is the sanctioned path
    p.unpack_into(chunk);
}

pub fn good_ordinary_bindings(n: usize) {
    let w = vec![0u8; n]; // packed state, not a mirror
    let dw_buf = vec![0f32; n]; // increments are legitimately f32
    drop((w, dw_buf));
}

#[cfg(test)]
mod tests {
    pub fn oracle_unpacks_are_exempt(p: &PackedTensor) -> Vec<f32> {
        p.unpack()
    }
}
