//! U1 fixture: even inside an audit home, every unsafe block needs its
//! own audit comment. Linted under the pseudo-path
//! `rust/src/util/align.rs`.

pub fn bad_missing_audit(x: &mut [u64]) -> *mut u64 {
    unsafe { x.as_mut_ptr().add(0) } // seed:U1
}

pub fn good_audited(x: &[u64]) -> u64 {
    // SAFETY: caller guarantees x is non-empty, so index 0 exists
    unsafe { *x.as_ptr() }
}
