//! D1 fixture: raw parallelism probes and detached spawns outside the
//! pool homes. Never compiled — linted by tests/lint.rs under the
//! pseudo-path `rust/src/util/fx_d1.rs`. Lines tagged `seed:<RULE>` are
//! the expected diagnostics.

pub fn bad_probe() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) // seed:D1
}

pub fn bad_spawn() {
    std::thread::spawn(|| {}); // seed:D1
}

pub fn bad_builder() {
    let b = std::thread::Builder::new(); // seed:D1
    let _ = b;
}

pub fn fine_scoped_workers() {
    // structured concurrency over caller-sized work is the sanctioned model
    std::thread::scope(|s| {
        s.spawn(|| {});
    });
}

pub fn suppressed_probe() -> usize {
    // lint:allow(D1): fixture proves a justified allow suppresses the probe
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    pub fn test_spawns_are_exempt() {
        std::thread::spawn(|| {});
    }
}
