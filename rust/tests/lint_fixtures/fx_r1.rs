//! R1 fixture: poison-cascading lock acquisition. Linted under the
//! pseudo-path `rust/src/util/fx_r1.rs`.

use std::sync::Mutex;

pub fn bad_lock_unwrap(m: &Mutex<u64>) -> u64 {
    *m.lock().unwrap() // seed:R1
}

pub fn bad_lock_expect(m: &Mutex<u64>) -> u64 {
    *m.lock().expect("not poisoned") // seed:R1
}

pub fn good_recover(m: &Mutex<u64>) -> u64 {
    *crate::util::lock::lock_recover(m)
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    pub fn test_unwraps_are_exempt(m: &Mutex<u64>) -> u64 {
        *m.lock().unwrap()
    }
}
