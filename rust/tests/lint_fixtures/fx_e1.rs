//! E1 fixture: float contamination inside the exact-integer kernel
//! bodies. Linted under the pseudo-path `rust/src/engine/bitplane.rs`,
//! where only `gated_dot*` and `dot_planes_word` bodies are scanned.

pub fn gated_dot_fx(pos: u64, active: u64) -> i64 {
    let leak = 0.5; // seed:E1
    let _ = leak;
    2 * (pos as i64) - (active as i64)
}

pub fn dot_planes_word(pos: u32, active: u32) -> u32 {
    let _ = (pos + active) as f32; // seed:E1
    pos
}

pub fn pack_row_scale_is_outside_the_exact_core(x: i64) -> f32 {
    // packers and GEMM wrappers legitimately scale to f32
    x as f32 * 0.0625
}
