//! U1 fixture: unsafe outside the audited homes. Linted under the
//! pseudo-path `rust/src/hwsim/fx_u1.rs` — not an audit home, so the
//! block is flagged even though it carries an audit comment.

pub fn bad_new_unsafe_surface(x: &[u32]) -> &[u8] {
    // SAFETY: a comment does not make a new unsafe home acceptable
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) } // seed:U1
}
