//! S1 fixture: suppression hygiene. Linted under the pseudo-path
//! `rust/src/util/fx_s1.rs`.

pub fn unjustified_allow_does_not_suppress() -> usize {
    // lint:allow(D1) // seed:S1
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) // seed:D1
}

// lint:allow(Z9): the rule Z9 does not exist in the catalog // seed:S1
pub fn unknown_rule() {}

// lint:allow(D1 — missing the closing parenthesis entirely // seed:S1
pub fn malformed() {}

pub fn justified_allow_suppresses() -> usize {
    // lint:allow(D1): fixture demonstrates a reviewed, justified exception
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
