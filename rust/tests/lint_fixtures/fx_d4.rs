//! D4 fixture: environment reads outside the configuration homes.
//! Linted under the pseudo-path `rust/src/data/fx_d4.rs`.

pub fn bad_env_read() -> Option<String> {
    std::env::var("GXNOR_SECRET_KNOB").ok() // seed:D4
}

pub fn bad_env_write() {
    std::env::set_var("GXNOR_MODE", "fast"); // seed:D4
}

pub fn fine_non_config_env() -> usize {
    // args/temp_dir are not invisible run configuration
    std::env::args().count() + std::env::temp_dir().components().count()
}
