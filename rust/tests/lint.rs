//! Tier-1 tests for `gxnor-lint`, the repo-invariant static analysis
//! pass (src/lint/).
//!
//! Two halves:
//!
//! 1. **Fixtures** — each file under `tests/lint_fixtures/` seeds known
//!    violations and tags every expected diagnostic with a
//!    `seed:<RULE>` marker on the violating line. The fixture is linted
//!    through `lint_source` under a pseudo-path that puts it in the
//!    rule's scope, and the produced (rule, line) set must equal the
//!    marker set exactly — extra diagnostics fail as loudly as missed
//!    ones, and the untagged "good" lines double as negative controls.
//!
//! 2. **The real tree** — `lint_tree` over this repository must come
//!    back empty. This is the same check CI runs via
//!    `gxnor-lint --deny-all`.

use std::path::Path;

use gxnor::lint::{lint_source, lint_tree, rules, Scope};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read fixture {}: {e}", p.display()))
}

/// Collect `seed:<RULE>` markers: the (rule, line) pairs the fixture
/// declares as its expected diagnostics. Markers with no rule id (prose
/// like "seed:<RULE>" in a doc header) are ignored.
fn expected(src: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for (idx, line) in src.lines().enumerate() {
        let mut rest = line;
        while let Some(p) = rest.find("seed:") {
            rest = &rest[p + 5..];
            let id: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric())
                .collect();
            if !id.is_empty() {
                assert!(
                    rules::rule(&id).is_some(),
                    "fixture marker names unknown rule `{id}` on line {}",
                    idx + 1
                );
                out.push((id, (idx + 1) as u32));
            }
        }
    }
    out.sort();
    out
}

/// Lint `name` as if it lived at `pseudo_rel` and require the diagnostic
/// set to match the fixture's markers exactly.
fn check_fixture(name: &str, pseudo_rel: &str) {
    let src = fixture(name);
    let want = expected(&src);
    assert!(
        !want.is_empty(),
        "fixture {name} declares no expected diagnostics — marker rot?"
    );
    let mut got: Vec<(String, u32)> = lint_source(pseudo_rel, &src)
        .into_iter()
        .map(|d| (d.rule.to_string(), d.line))
        .collect();
    got.sort();
    assert_eq!(
        got, want,
        "fixture {name} (as {pseudo_rel}): diagnostics != seed markers"
    );
}

#[test]
fn d1_parallelism_probes_and_spawns() {
    check_fixture("fx_d1.rs", "rust/src/util/fx_d1.rs");
}

#[test]
fn d2_wall_clock_reads() {
    check_fixture("fx_d2.rs", "rust/src/serve/queue.rs");
}

#[test]
fn d3_hash_ordered_containers() {
    check_fixture("fx_d3.rs", "rust/src/engine/fx_d3.rs");
}

#[test]
fn d4_env_reads_outside_homes() {
    check_fixture("fx_d4.rs", "rust/src/data/fx_d4.rs");
}

#[test]
fn e1_float_in_exact_kernels() {
    check_fixture("fx_e1.rs", "rust/src/engine/bitplane.rs");
}

#[test]
fn m1_weight_mirrors_in_step_loop() {
    check_fixture("fx_m1.rs", "rust/src/coordinator/trainer.rs");
}

#[test]
fn r1_lock_unwrap() {
    check_fixture("fx_r1.rs", "rust/src/util/fx_r1.rs");
}

#[test]
fn r2_serve_path_panics() {
    check_fixture("fx_r2.rs", "rust/src/serve/fx_r2.rs");
}

#[test]
fn u1_unsafe_outside_homes() {
    check_fixture("fx_u1_outside.rs", "rust/src/hwsim/fx_u1.rs");
}

#[test]
fn u1_unsafe_home_needs_safety_comment() {
    check_fixture("fx_u1_home.rs", "rust/src/util/align.rs");
}

#[test]
fn s1_suppression_hygiene() {
    check_fixture("fx_s1.rs", "rust/src/util/fx_s1.rs");
}

/// The D4 fixture would be clean if it lived in a config home: the same
/// source linted under util/pool.rs produces no D4 diagnostics.
#[test]
fn d4_homes_are_exempt() {
    let src = fixture("fx_d4.rs");
    let diags = lint_source("rust/src/util/pool.rs", &src);
    assert!(
        diags.iter().all(|d| d.rule != "D4"),
        "D4 fired inside a config home: {diags:?}"
    );
}

/// Moving the E1 fixture out of bitplane.rs disarms the kernel rule —
/// it is scoped to the one file holding the exact-integer core.
#[test]
fn e1_is_scoped_to_bitplane() {
    let src = fixture("fx_e1.rs");
    let diags = lint_source("rust/src/engine/mod.rs", &src);
    assert!(
        diags.is_empty(),
        "E1 escaped its file scope: {diags:?}"
    );
}

/// S1 itself can never be suppressed: an allow targeting S1 placed on an
/// unjustified allow still leaves the S1 diagnostic standing.
#[test]
fn s1_is_not_suppressible() {
    // Build the comment markers at runtime so this file's own source
    // never contains a parseable suppression.
    let allow = |body: &str| format!("// lint{}allow({body})\n", ':');
    let src = format!(
        "{}{}fn f() {{}}\n",
        allow("S1): trying to silence the suppression auditor itself"),
        allow("D2") // unjustified -> S1 on this line
    );
    let diags = lint_source("rust/src/util/x.rs", &src);
    assert!(
        diags.iter().any(|d| d.rule == "S1" && d.line == 2),
        "unjustified allow must raise S1 even under an S1-allow: {diags:?}"
    );
}

/// Test code is exempt from the panic/determinism rules but suppressions
/// are still audited there.
#[test]
fn test_files_keep_suppression_hygiene() {
    let allow = |body: &str| format!("// lint{}allow({body})\n", ':');
    let src = format!("{}fn f() {{}}\n", allow("QQ): not a rule that exists"));
    let diags = lint_source("rust/tests/some_test.rs", &src);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].rule, "S1");
}

/// Rule catalog sanity: ids unique and non-empty rationale for
/// `--explain`, and the scope derivation agrees with the catalog's two
/// unsafe homes.
#[test]
fn rule_catalog_is_well_formed() {
    let mut ids: Vec<&str> = rules::RULES.iter().map(|r| r.id).collect();
    assert!(ids.len() >= 10, "catalog shrank to {} rules", ids.len());
    ids.sort();
    let before = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), before, "duplicate rule ids");
    for r in rules::RULES {
        assert!(!r.title.is_empty() && !r.scope.is_empty(), "{}", r.id);
        assert!(
            r.rationale.len() > 100,
            "{}: --explain rationale too thin",
            r.id
        );
        assert!(rules::rule(r.id).is_some());
    }
    assert!(Scope::for_path("rust/src/util/align.rs").unsafe_home);
    assert!(Scope::for_path("rust/src/runtime/client.rs").unsafe_home);
    assert!(!Scope::for_path("rust/src/util/pool.rs").unsafe_home);
}

/// The check CI runs: the real tree, linted from the repo root, is
/// clean. Any new violation must either be fixed or carry a justified
/// `allow` — and this test names the exact file:line when it fails.
#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let diags = lint_tree(&root).expect("walk repo tree");
    if !diags.is_empty() {
        let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
        panic!(
            "gxnor-lint found {} violation(s) in the real tree:\n{}",
            diags.len(),
            listing.join("\n")
        );
    }
}
