//! Integration tests across the full stack: manifest -> PJRT runtime ->
//! trainer -> DST updates -> evaluation, plus cross-layer property tests
//! tying the Rust DST to the paper's equations.
//!
//! These tests need `make artifacts` to have run (they use the b16 MLP
//! graphs, which are cheap); they skip gracefully when artifacts are
//! missing so `cargo test` stays runnable pre-AOT.

use gxnor::coordinator::checkpoint;
use gxnor::coordinator::method::Method;
use gxnor::coordinator::optimizer::OptKind;
use gxnor::coordinator::trainer::{TrainConfig, Trainer};
use gxnor::data::{self, Dataset};
use gxnor::ptest::{property, Gen};
use gxnor::runtime::client::Runtime;
use gxnor::runtime::manifest::Manifest;
use gxnor::ternary::{dst_update, DiscreteSpace};

fn manifest() -> Option<Manifest> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some(Manifest::load("artifacts").unwrap())
    } else {
        eprintln!("skipping integration test: run `make artifacts`");
        None
    }
}

fn small_cfg(method: Method) -> TrainConfig {
    TrainConfig {
        arch: "mlp".into(),
        method,
        dataset: "synth_mnist".into(),
        train_len: 600,
        test_len: 200,
        epochs: 2,
        seed: 7,
        verbose: false,
        ..Default::default()
    }
}

/// Pick the b16 graphs for fast tests by shadowing the batch>16 preference:
/// we simply filter the manifest down to b16 graphs.
fn b16_manifest(m: &Manifest) -> Manifest {
    let mut m2 = m.clone();
    m2.graphs.retain(|g| g.batch == 16 || g.mode != "multi");
    m2
}

#[test]
fn gxnor_training_learns_and_stays_on_grid() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let cfg = small_cfg(Method::Gxnor);
    let train = data::open(&cfg.dataset, true, cfg.train_len).unwrap();
    let test = data::open(&cfg.dataset, false, cfg.test_len).unwrap();
    let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
    assert_eq!(tr.batch_size(), 16);
    let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
    // learning happened (chance = 10%)
    assert!(
        report.test_acc > 0.3,
        "gxnor failed to learn: {:.1}%",
        100.0 * report.test_acc
    );
    // paper's core invariant: every weight is exactly in {-1, 0, 1}
    let space = DiscreteSpace::TERNARY;
    for (d, v) in tr.model.descs.iter().zip(&tr.model.values) {
        if d.kind == gxnor::nn::params::ParamKind::Weight {
            for w in v.to_f32() {
                assert!(space.contains(w), "{}: off-grid weight {w}", d.name);
            }
        }
    }
    // memory claim: packed weights ~16x below f32
    assert!(report.fp32_bytes as f64 / report.packed_bytes as f64 > 12.0);
    // loss decreased
    let losses = report.recorder.get("epoch_loss");
    assert!(losses.last().unwrap() < losses.first().unwrap());
}

#[test]
fn all_table1_methods_run_on_mlp() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    for method in [Method::Fp, Method::Bnn, Method::Gxnor] {
        let cfg = TrainConfig { epochs: 1, ..small_cfg(method) };
        let train = data::open("synth_mnist", true, 600).unwrap();
        let test = data::open("synth_mnist", false, 200).unwrap();
        let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
        let report = tr.run(train.as_ref(), test.as_ref()).unwrap();
        assert!(
            report.test_acc > 0.15,
            "{}: {:.1}%",
            method.name(),
            100.0 * report.test_acc
        );
    }
}

#[test]
fn bwn_twn_share_fp_graph_with_discrete_weights() {
    let Some(m) = manifest() else { return };
    let mut rt = Runtime::new().unwrap();
    for (method, n_states) in [(Method::Bwn, 2usize), (Method::Twn, 3usize)] {
        let cfg = TrainConfig { epochs: 1, ..small_cfg(method) };
        let train = data::open("synth_mnist", true, 600).unwrap();
        let test = data::open("synth_mnist", false, 200).unwrap();
        let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
        assert!(tr.graph_name().contains("_fp_"), "{}", tr.graph_name());
        tr.run(train.as_ref(), test.as_ref()).unwrap();
        let space = method.weight_space().unwrap();
        assert_eq!(space.n_states(), n_states);
        for (d, v) in tr.model.descs.iter().zip(&tr.model.values) {
            if d.kind == gxnor::nn::params::ParamKind::Weight {
                for w in v.to_f32() {
                    assert!(space.contains(w), "{}: off-grid {w}", method.name());
                }
            }
        }
    }
}

#[test]
fn multilevel_spaces_run_and_respect_n1() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let method = Method::Multi { n1: 3, n2: 2 };
    let cfg = TrainConfig { epochs: 1, ..small_cfg(method) };
    let train = data::open("synth_mnist", true, 400).unwrap();
    let test = data::open("synth_mnist", false, 160).unwrap();
    let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
    tr.run(train.as_ref(), test.as_ref()).unwrap();
    let space = DiscreteSpace::new(3);
    let hist = tr.model.weight_histogram();
    assert_eq!(hist.len(), space.n_states());
    // intermediate states are actually used (multi-hop transitions happened)
    let interior: u64 = hist[1..hist.len() - 1].iter().sum();
    assert!(interior > 0, "no interior states used: {hist:?}");
}

#[test]
fn checkpoint_roundtrip_preserves_accuracy() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let cfg = small_cfg(Method::Gxnor);
    let train = data::open("synth_mnist", true, 600).unwrap();
    let test = data::open("synth_mnist", false, 200).unwrap();
    let path = std::env::temp_dir().join(format!("gxnor_it_{}.ckpt", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    let acc_before;
    {
        let mut tr = Trainer::new(&mut rt, &m, cfg.clone()).unwrap();
        tr.run(train.as_ref(), test.as_ref()).unwrap();
        acc_before = tr.evaluate(test.as_ref()).unwrap();
        checkpoint::save(&tr.model, &path_s).unwrap();
    }
    {
        let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
        checkpoint::load(&mut tr.model, &path_s).unwrap();
        let acc_after = tr.evaluate(test.as_ref()).unwrap();
        assert!(
            (acc_before - acc_after).abs() < 1e-9,
            "{acc_before} vs {acc_after}"
        );
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn sparsity_knob_r_moves_measured_sparsity() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let mut accs = Vec::new();
    for r in [0.1f32, 0.9f32] {
        let cfg = TrainConfig { r, epochs: 1, ..small_cfg(Method::Gxnor) };
        let train = data::open("synth_mnist", true, 400).unwrap();
        let test = data::open("synth_mnist", false, 160).unwrap();
        let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
        let rep = tr.run(train.as_ref(), test.as_ref()).unwrap();
        accs.push(rep.mean_act_sparsity);
    }
    assert!(
        accs[1] > accs[0] + 0.1,
        "sparsity should grow with r: {accs:?}"
    );
}

#[test]
fn dst_sgd_mode_has_zero_fp_state() {
    // the paper's pure no-full-precision-memory configuration
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let cfg = TrainConfig {
        opt: OptKind::Sgd,
        lr_start: 0.02,
        lr_fin: 0.005,
        epochs: 3,
        train_len: 1200,
        ..small_cfg(Method::Gxnor)
    };
    let train = data::open("synth_mnist", true, 1200).unwrap();
    let test = data::open("synth_mnist", false, 200).unwrap();
    let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
    let rep = tr.run(train.as_ref(), test.as_ref()).unwrap();
    assert!(rep.test_acc > 0.25, "{:.1}%", 100.0 * rep.test_acc);
}

#[test]
fn hidden_weight_rule_trains_and_reports_master_memory() {
    // the Fig. 4a baseline: fp masters exist, quantized view stays on grid
    use gxnor::coordinator::trainer::UpdateRule;
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let cfg = TrainConfig {
        update_rule: UpdateRule::Hidden,
        ..small_cfg(Method::Gxnor)
    };
    let train = data::open("synth_mnist", true, 600).unwrap();
    let test = data::open("synth_mnist", false, 200).unwrap();
    let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
    let rep = tr.run(train.as_ref(), test.as_ref()).unwrap();
    assert!(rep.test_acc > 0.3, "{:.1}%", 100.0 * rep.test_acc);
    // masters cost exactly 4 B per weight
    assert_eq!(rep.hidden_fp32_bytes, 4 * tr.model.n_weights());
    // quantized view still strictly on-grid
    let space = DiscreteSpace::TERNARY;
    for (d, v) in tr.model.descs.iter().zip(&tr.model.values) {
        if d.kind == gxnor::nn::params::ParamKind::Weight {
            for w in v.to_f32() {
                assert!(space.contains(w), "off-grid {w}");
            }
        }
    }
    // and DST mode reports zero master memory
    let cfg2 = small_cfg(Method::Gxnor);
    let mut tr2 = Trainer::new(&mut rt, &m, cfg2).unwrap();
    let rep2 = tr2.run(train.as_ref(), test.as_ref()).unwrap();
    assert_eq!(rep2.hidden_fp32_bytes, 0);
}

#[test]
fn checkpoint_inspect_describes_tensors() {
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let cfg = TrainConfig { epochs: 1, ..small_cfg(Method::Gxnor) };
    let train = data::open("synth_mnist", true, 320).unwrap();
    let test = data::open("synth_mnist", false, 160).unwrap();
    let mut tr = Trainer::new(&mut rt, &m, cfg).unwrap();
    tr.run(train.as_ref(), test.as_ref()).unwrap();
    let bytes = checkpoint::serialize(&tr.model);
    let desc = checkpoint::inspect(&bytes).unwrap();
    assert!(desc.contains("W0"), "{desc}");
    assert!(desc.contains("Z_1"), "{desc}");
    assert!(desc.contains("bn state"), "{desc}");
    assert!(desc.contains("packed weights"), "{desc}");
}

#[test]
fn training_trajectory_bit_reproducible_with_prefetcher() {
    // The pipelined prefetcher + pooled boundary must not perturb the
    // math: two runs from the same TrainConfig produce identical loss and
    // accuracy trajectories (the prefetcher replays the serial iterator's
    // per-epoch RNG streams; dirty-tracking only skips no-op refills).
    let Some(m) = manifest() else { return };
    let m = b16_manifest(&m);
    let mut rt = Runtime::new().unwrap();
    let run_once = |rt: &mut Runtime| {
        let cfg = small_cfg(Method::Gxnor);
        let train = data::open(&cfg.dataset, true, cfg.train_len).unwrap();
        let test = data::open(&cfg.dataset, false, cfg.test_len).unwrap();
        let mut tr = Trainer::new(rt, &m, cfg).unwrap();
        let rep = tr.run(train.as_ref(), test.as_ref()).unwrap();
        (
            rep.recorder.get("loss").to_vec(),
            rep.recorder.get("test_acc").to_vec(),
            rep.test_acc,
        )
    };
    let (loss1, acc1, t1) = run_once(&mut rt);
    let (loss2, acc2, t2) = run_once(&mut rt);
    assert_eq!(loss1, loss2, "loss trajectories diverge");
    assert_eq!(acc1, acc2, "test-acc trajectories diverge");
    assert_eq!(t1, t2);
}

// ---------------------------------------------------------------------------
// Cross-layer property tests (ptest harness)
// ---------------------------------------------------------------------------

#[test]
fn prop_dst_preserves_grid_and_bounds() {
    property("dst grid closure", 300, |g: &mut Gen| {
        let n = g.usize_in(0, 7) as u32;
        let space = DiscreteSpace::new(n);
        let len = g.usize_in(1, 300);
        let mut w: Vec<f32> = (0..len)
            .map(|_| space.state(g.usize_in(0, space.n_states())))
            .collect();
        let dw = g.vec_normal(len, 2.0);
        let m = g.f32_in(0.1, 10.0);
        dst_update(&mut w, &dw, space, m, g.rng(), 1);
        for &v in &w {
            if !space.contains(v) {
                return Err(format!("N={n}: {v} off grid"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dst_zero_increment_fixed_point() {
    property("dst zero fixed point", 100, |g: &mut Gen| {
        let n = g.usize_in(0, 7) as u32;
        let space = DiscreteSpace::new(n);
        let len = g.usize_in(1, 100);
        let w0: Vec<f32> = (0..len)
            .map(|_| space.state(g.usize_in(0, space.n_states())))
            .collect();
        let mut w = w0.clone();
        let dw = vec![0.0f32; len];
        dst_update(&mut w, &dw, space, 3.0, g.rng(), 1);
        if w != w0 {
            return Err("zero increment moved weights".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dst_monotone_in_expectation() {
    // positive increments never *decrease* a weight (single draw can only
    // hop along sign(rho)): check per-element next >= current for dw >= 0.
    property("dst monotone", 200, |g: &mut Gen| {
        let space = DiscreteSpace::new(g.usize_in(1, 7) as u32);
        let len = g.usize_in(1, 200);
        let w0: Vec<f32> = (0..len)
            .map(|_| space.state(g.usize_in(0, space.n_states())))
            .collect();
        let mut w = w0.clone();
        let dw: Vec<f32> = (0..len).map(|_| g.f32_in(0.0, 3.0)).collect();
        dst_update(&mut w, &dw, space, 3.0, g.rng(), 1);
        for (i, (&before, &after)) in w0.iter().zip(&w).enumerate() {
            if after < before - 1e-6 {
                return Err(format!("w[{i}] moved against dw: {before} -> {after}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_augment_preserves_range() {
    use gxnor::data::augment::{augment, AugmentCfg};
    property("augment range", 100, |g: &mut Gen| {
        let h = g.usize_in(4, 33);
        let w = g.usize_in(4, 33);
        let c = *g.choose(&[1usize, 3]);
        let mut img = g.vec_f32(h * w * c, -1.0, 1.0);
        let cfg = AugmentCfg { pad: g.usize_in(0, 5), hflip: g.bool() };
        augment(&mut img, h, w, c, &cfg, g.rng());
        if img.len() != h * w * c {
            return Err("length changed".into());
        }
        for &v in &img {
            if !(-1.0..=1.0).contains(&v) {
                return Err(format!("out of range {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_packed_tensor_roundtrip() {
    use gxnor::ternary::PackedTensor;
    property("packed roundtrip", 150, |g: &mut Gen| {
        let n = g.usize_in(0, 7) as u32;
        let space = DiscreteSpace::new(n);
        let len = g.usize_in(1, 1000);
        let vals: Vec<f32> = (0..len)
            .map(|_| space.state(g.usize_in(0, space.n_states())))
            .collect();
        let p = PackedTensor::pack(&vals, &[len], space);
        if p.unpack() != vals {
            return Err(format!("roundtrip failed for N={n} len={len}"));
        }
        let mut buf = Vec::new();
        p.serialize(&mut buf);
        let mut pos = 0;
        let q = PackedTensor::deserialize(&buf, &mut pos).map_err(|e| e)?;
        if q.unpack() != vals {
            return Err("serialize roundtrip failed".into());
        }
        Ok(())
    });
}

#[test]
fn eval_batches_agree_with_direct_fill() {
    // BatchIter::for_eval must enumerate the dataset in order
    let ds = data::open("synth_cifar", false, 64).unwrap();
    let mut labels = Vec::new();
    gxnor::data::BatchIter::for_eval(ds.as_ref(), 16, |_, y| {
        labels.extend_from_slice(y)
    });
    let mut buf = vec![0.0; ds.sample_len()];
    for (i, &l) in labels.iter().enumerate() {
        assert_eq!(l, ds.fill(i, &mut buf) as i32);
    }
}
