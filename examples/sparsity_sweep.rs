//! Fig. 10 scenario: the zero window `r` controls the fraction of resting
//! activations. Sweep r, measure the *actual* zero-activation fraction and
//! test accuracy, and feed the measured sparsity into the hardware
//! simulator to show the accuracy/energy trade-off the paper's Section 3.B
//! discusses ("a sparser network can be more hardware friendly").
//!
//! It runs **device-free** on the native DST backend: no lowered
//! artifacts and no PJRT client are needed (a manifest, when present,
//! only contributes shapes/batch size).
//!
//! ```sh
//! cargo run --release --example sparsity_sweep
//! ```

use gxnor::coordinator::trainer::{TrainBackend, TrainConfig};
use gxnor::hwsim::{expected_counts, EnergyModel, NetArch};
use gxnor::runtime::exec::EngineKind;
use gxnor::runtime::manifest::Manifest;
use gxnor::sweep;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts").ok();
    if manifest.is_none() {
        println!("no artifacts/manifest.json: using catalogue shapes (fully device-free)");
    }
    let mut backend = TrainBackend::Native { manifest: manifest.as_ref() };
    let base = TrainConfig {
        train_len: 3000,
        test_len: 800,
        epochs: 3,
        engine: EngineKind::Native,
        verbose: false,
        ..Default::default()
    };
    let rs = [0.05, 0.2, 0.35, 0.5, 0.65, 0.8, 0.95];
    println!("sweeping zero-window r over {rs:?} (3 epochs each)…\n");
    let points = sweep::sweep_scalar(&mut backend, &base, "r", &rs)?;
    let energy = EnergyModel::default();
    let m = 1000u64;
    let fp_base = expected_counts(NetArch::FullPrecision, m, 0.0, 0.0);

    println!(
        "{:>6} {:>10} {:>14} {:>12} {:>12}",
        "r", "test_acc", "act_sparsity", "resting_p", "rel_energy"
    );
    for p in &points {
        // feed measured sparsity into the Table-2 machinery
        let counts = expected_counts(
            NetArch::Gxnor,
            m,
            p.weight_zero_fraction,
            p.act_sparsity,
        );
        println!(
            "{:>6.2} {:>9.2}% {:>14.3} {:>11.1}% {:>12.5}",
            p.value.unwrap_or(f64::NAN),
            100.0 * p.test_acc,
            p.act_sparsity,
            100.0 * counts.resting_probability(),
            energy.relative(&counts, &fp_base),
        );
    }
    if let Some(best) = sweep::best(&points) {
        println!(
            "\nbest accuracy at {} — an interior sparsity, as in Fig. 10 \
             (too sparse starves the network, too dense loses the regularizer)",
            best.label
        );
    }
    Ok(())
}
