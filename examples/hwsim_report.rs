//! Table 2 + Fig. 11/12 scenario: event-driven hardware analysis.
//!
//! Prints (a) the analytic Table 2 under the paper's uniform-state
//! assumption, (b) the Fig. 12 gating example (21 XNOR -> ~9), and (c) a
//! *measured* Table 2 using weight/activation statistics from an actually
//! trained GXNOR model — the paper's own caveat that "the reported values
//! can only be used as rough guidelines" made quantitative. Training and
//! inference run on the device-free native backend; the final section
//! cross-checks the resting rate the packed kernels *executed* against
//! the analytic prediction, layer by layer.
//!
//! ```sh
//! cargo run --release --example hwsim_report
//! ```

use gxnor::coordinator::trainer::{evaluate_engine, NativeTrainer, TrainConfig};
use gxnor::data;
use gxnor::engine::NativeEngine;
use gxnor::hwsim::report::{fig12_example, measured_vs_analytic, table2};
use gxnor::runtime::exec::EngineKind;
use gxnor::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    println!("— Table 2 (analytic, uniform states: p0 = 1/3) —\n");
    print!("{}", table2(100, 1.0 / 3.0, 1.0 / 3.0));

    let (nominal, mean) = fig12_example(20_000, 7);
    println!(
        "\n— Fig. 12 — {nominal} nominal XNOR ops -> {mean:.2} active on average \
         (paper: 21 -> 9)\n"
    );

    // measured mode: train a small GXNOR net device-free and reuse its
    // statistics (a manifest, when present, only contributes shapes)
    let manifest = Manifest::load("artifacts").ok();
    let cfg = TrainConfig {
        train_len: 2000,
        test_len: 500,
        epochs: 2,
        engine: EngineKind::Native,
        verbose: false,
        ..Default::default()
    };
    println!("training a GXNOR MLP to measure real state distributions…");
    let train = data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    let test = data::open(&cfg.dataset, false, cfg.test_len).map_err(anyhow::Error::msg)?;
    let mut tr = NativeTrainer::new(manifest.as_ref(), cfg.clone())?;
    let report = tr.run(train.as_ref(), test.as_ref())?;
    println!(
        "measured: weight zero fraction {:.3}, activation sparsity {:.3}\n",
        report.weight_zero_fraction, report.mean_act_sparsity
    );
    println!("— Table 2 (measured state distributions) —\n");
    print!(
        "{}",
        table2(100, report.weight_zero_fraction, report.mean_act_sparsity)
    );

    // loop closure: the resting rate the packed kernels executed over the
    // test set must match the analytic model fed with measured zero-state
    // fractions (tolerance covers trained-tensor correlations)
    let mut eng =
        NativeEngine::from_model(&cfg.arch, cfg.method, &tr.model, cfg.r, 100, 10, 0)?;
    evaluate_engine(&mut eng, test.as_ref())?;
    let (gate_table, gate_ok) = measured_vs_analytic(&eng.gate_report(), 0.10);
    println!("\n— executed kernels vs Table 2 —\n");
    print!("{gate_table}");
    assert!(
        gate_ok,
        "measured resting rate diverges from the Table 2 analytic prediction"
    );
    println!(
        "\nNote: trained networks are sparser than uniform in activations and\n\
         denser in weights; the GXNOR resting probability moves accordingly."
    );
    Ok(())
}
