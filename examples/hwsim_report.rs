//! Table 2 + Fig. 11/12 scenario: event-driven hardware analysis.
//!
//! Prints (a) the analytic Table 2 under the paper's uniform-state
//! assumption, (b) the Fig. 12 gating example (21 XNOR -> ~9), and (c) a
//! *measured* Table 2 using weight/activation statistics from an actually
//! trained GXNOR model — the paper's own caveat that "the reported values
//! can only be used as rough guidelines" made quantitative.
//!
//! ```sh
//! make artifacts && cargo run --release --example hwsim_report
//! ```

use gxnor::coordinator::trainer::{run_training, TrainConfig};
use gxnor::hwsim::report::{fig12_example, table2};
use gxnor::runtime::client::Runtime;
use gxnor::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    println!("— Table 2 (analytic, uniform states: p0 = 1/3) —\n");
    print!("{}", table2(100, 1.0 / 3.0, 1.0 / 3.0));

    let (nominal, mean) = fig12_example(20_000, 7);
    println!(
        "\n— Fig. 12 — {nominal} nominal XNOR ops -> {mean:.2} active on average \
         (paper: 21 -> 9)\n"
    );

    // measured mode: train a small GXNOR net and reuse its statistics
    let manifest = Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
    let mut rt = Runtime::new()?;
    let cfg = TrainConfig {
        train_len: 2000,
        test_len: 500,
        epochs: 2,
        verbose: false,
        ..Default::default()
    };
    println!("training a GXNOR MLP to measure real state distributions…");
    let report = run_training(&mut rt, &manifest, cfg)?;
    println!(
        "measured: weight zero fraction {:.3}, activation sparsity {:.3}\n",
        report.weight_zero_fraction, report.mean_act_sparsity
    );
    println!("— Table 2 (measured state distributions) —\n");
    print!(
        "{}",
        table2(100, report.weight_zero_fraction, report.mean_act_sparsity)
    );
    println!(
        "\nNote: trained networks are sparser than uniform in activations and\n\
         denser in weights; the GXNOR resting probability moves accordingly."
    );
    Ok(())
}
