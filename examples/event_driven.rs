//! Whole-network event-driven analysis (Section 3.C at network scale):
//! train the paper's MNIST CNN briefly as a GXNOR-Net on the device-free
//! native backend, run the test set through the packed-domain inference
//! engine, and drive the hardware simulator from the gate tallies the
//! kernels *actually executed* — tile skips, event lists and all — next
//! to the analytic Fig. 11 families. The GXNOR row of the final table is
//! measured, not assumed.
//!
//! ```sh
//! cargo run --release --example event_driven
//! ```

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{evaluate_engine, NativeTrainer, TrainConfig};
use gxnor::data;
use gxnor::engine::NativeEngine;
use gxnor::hwsim::report::measured_vs_analytic;
use gxnor::hwsim::{measured_network_counts, network_counts, render_network_table, NetArch};
use gxnor::nn::arch::build_arch;
use gxnor::runtime::exec::EngineKind;
use gxnor::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts").ok();
    if manifest.is_none() {
        println!("no artifacts/manifest.json: using catalogue shapes (fully device-free)");
    }
    let cfg = TrainConfig {
        arch: "cnn_mnist".into(),
        method: Method::Gxnor,
        train_len: 1500,
        test_len: 300,
        epochs: 1,
        engine: EngineKind::Native,
        verbose: true,
        ..Default::default()
    };
    println!("training the paper's MNIST CNN briefly to measure state distributions…");
    let train = data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    let test = data::open(&cfg.dataset, false, cfg.test_len).map_err(anyhow::Error::msg)?;
    let mut tr = NativeTrainer::new(manifest.as_ref(), cfg.clone())?;
    tr.run(train.as_ref(), test.as_ref())?;

    // forward the test set once more through a fresh inference engine and
    // keep its per-layer gate tallies: this is what the kernels executed,
    // adaptive strategy dispatch included
    let mut eng =
        NativeEngine::from_model(&cfg.arch, cfg.method, &tr.model, cfg.r, 100, 10, 0)?;
    let acc = evaluate_engine(&mut eng, test.as_ref())?;
    let reports = eng.gate_report();
    println!("\ntest accuracy {:.2}% — measured per-layer gating:\n", 100.0 * acc);
    let (gate_table, gate_ok) = measured_vs_analytic(&reports, 0.10);
    print!("{gate_table}");
    assert!(
        gate_ok,
        "measured resting rate diverges from the Table 2 analytic prediction"
    );

    // the Fig. 11 comparison table: analytic rows for the other families,
    // *measured* per-sample counts for the GXNOR row
    let arch = build_arch(&cfg.arch).map_err(anyhow::Error::msg)?;
    let pw0 = tr.model.weight_zero_fraction();
    let mut px0 = vec![0.0f64]; // input layer: real-valued, no zeros
    px0.extend(reports.iter().map(|r| r.stats.x_zero_fraction()));
    let by_net: Vec<_> = NetArch::ALL
        .iter()
        .map(|&net| {
            let reps = if net == NetArch::Gxnor {
                measured_network_counts(&arch, &reports, pw0)
            } else {
                network_counts(&arch, net, pw0, &px0)
            };
            (net, reps)
        })
        .collect();
    print!(
        "\n{}",
        render_network_table("cnn_mnist (32C5-MP2-64C5-MP2-512FC-SVM)", &by_net)
    );
    println!(
        "\nGXNOR rests the most units of any architecture — the event-driven\n\
         win the paper's Fig. 11(f)/Fig. 12 describe, here measured from the\n\
         executed packed-domain kernels at network scale."
    );
    Ok(())
}
