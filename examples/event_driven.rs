//! Whole-network event-driven analysis (Section 3.C at network scale):
//! train the paper's MNIST CNN briefly as a GXNOR-Net, measure the *real*
//! per-layer activation sparsity and weight state distribution, and walk
//! every layer of every Fig. 11 architecture through the hardware
//! simulator — the per-layer operation/resting/energy table that Table 2
//! summarizes for a single neuron.
//!
//! ```sh
//! make artifacts && cargo run --release --example event_driven
//! ```

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{TrainConfig, Trainer};
use gxnor::data;
use gxnor::hwsim::{network_counts, render_network_table, NetArch};
use gxnor::nn::arch::build_arch;
use gxnor::runtime::client::Runtime;
use gxnor::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
    let mut rt = Runtime::new()?;
    let cfg = TrainConfig {
        arch: "cnn_mnist".into(),
        method: Method::Gxnor,
        train_len: 1500,
        test_len: 300,
        epochs: 1,
        verbose: true,
        ..Default::default()
    };
    println!("training the paper's MNIST CNN briefly to measure state distributions…");
    let train = data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    let test = data::open(&cfg.dataset, false, cfg.test_len).map_err(anyhow::Error::msg)?;
    let mut tr = Trainer::new(&mut rt, &manifest, cfg)?;
    let rep = tr.run(train.as_ref(), test.as_ref())?;

    // measured distributions
    let pw0 = tr.model.weight_zero_fraction();
    let n_hidden = tr
        .model
        .bn_state
        .len()
        / 2;
    let mut px0 = vec![0.0f64]; // input layer: real-valued, no zeros
    for j in 0..n_hidden {
        px0.push(rep.recorder.tail_mean(&format!("act_sparsity_l{j}"), 10));
    }
    println!(
        "\nmeasured: weight p0 = {pw0:.3}, per-layer activation p0 = {:?}\n",
        px0.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );

    let arch = build_arch("cnn_mnist").map_err(anyhow::Error::msg)?;
    let by_net: Vec<_> = NetArch::ALL
        .iter()
        .map(|&net| (net, network_counts(&arch, net, pw0, &px0)))
        .collect();
    print!("{}", render_network_table("cnn_mnist (32C5-MP2-64C5-MP2-512FC-SVM)", &by_net));
    println!(
        "\nGXNOR rests the most units of any architecture — the event-driven\n\
         win the paper's Fig. 11(f)/Fig. 12 describe, here at network scale."
    );
    Ok(())
}
