//! Quickstart: train a GXNOR-Net (ternary weights *and* activations, no
//! full-precision hidden weights) on the procedural digit dataset and
//! verify the paper's core invariants from the public API.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{TrainConfig, Trainer};
use gxnor::data;
use gxnor::nn::params::ParamKind;
use gxnor::runtime::client::Runtime;
use gxnor::runtime::manifest::Manifest;
use gxnor::ternary::DiscreteSpace;

fn main() -> anyhow::Result<()> {
    // 1. the artifact manifest describes every lowered graph
    let manifest = Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
    let mut rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());

    // 2. configure the paper's headline method: GXNOR (N1 = N2 = 1)
    let cfg = TrainConfig {
        arch: "mlp".into(),
        method: Method::Gxnor,
        dataset: "synth_mnist".into(),
        train_len: 4000,
        test_len: 1000,
        epochs: 4,
        verbose: true,
        ..Default::default()
    };

    let train = data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    let test = data::open(&cfg.dataset, false, cfg.test_len).map_err(anyhow::Error::msg)?;

    // 3. train: fwd/bwd runs as one AOT-compiled XLA graph; the DST weight
    //    update (eqs. 13-20) runs in Rust, weights never leave {-1, 0, 1}
    let mut trainer = Trainer::new(&mut rt, &manifest, cfg)?;
    let report = trainer.run(train.as_ref(), test.as_ref())?;

    println!("\n— results —");
    println!("test accuracy         : {:.2}%", 100.0 * report.test_acc);
    println!(
        "activation sparsity   : {:.3} (zero fraction; r controls this)",
        report.mean_act_sparsity
    );
    println!("weight zero fraction  : {:.3}", report.weight_zero_fraction);
    println!(
        "weight memory         : {} B packed / {} B fp32 ({:.1}x)",
        report.packed_bytes,
        report.fp32_bytes,
        report.fp32_bytes as f64 / report.packed_bytes as f64
    );

    // 4. verify the paper's invariant: every weight is exactly ternary
    let space = DiscreteSpace::TERNARY;
    let mut checked = 0usize;
    for (d, v) in trainer.model.descs.iter().zip(&trainer.model.values) {
        if d.kind == ParamKind::Weight {
            for w in v.to_f32() {
                assert!(space.contains(w), "off-grid weight {w}");
                checked += 1;
            }
        }
    }
    println!("verified {checked} weights ∈ {{-1, 0, 1}} — no hidden fp weights anywhere");
    Ok(())
}
