//! End-to-end driver (EXPERIMENTS.md §End-to-end): trains the paper's
//! MNIST CNN "32C5-MP2-64C5-MP2-512FC-SVM" with the full GXNOR stack —
//! AOT-lowered JAX/Pallas forward/backward graph executed via PJRT from
//! Rust, DST weight updates in Rust, ternary weights end to end — for a
//! few hundred steps, logging the loss curve, then evaluates, checkpoints,
//! reloads and re-verifies.
//!
//! Uses real MNIST if `data/mnist/` holds the IDX files, otherwise the
//! procedural digit dataset (same code path; DESIGN.md §6).
//!
//! ```sh
//! make artifacts && cargo run --release --example train_mnist
//! ```

use gxnor::coordinator::checkpoint;
use gxnor::coordinator::method::Method;
use gxnor::coordinator::trainer::{TrainConfig, Trainer};
use gxnor::data;
use gxnor::runtime::client::Runtime;
use gxnor::runtime::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts").map_err(anyhow::Error::msg)?;
    let mut rt = Runtime::new()?;

    // prefer real MNIST when present
    let dataset = if std::path::Path::new("data/mnist/train-images-idx3-ubyte").exists() {
        "mnist"
    } else {
        "synth_mnist"
    };
    let cfg = TrainConfig {
        arch: "cnn_mnist".into(),
        method: Method::Gxnor,
        dataset: dataset.into(),
        train_len: 6000,
        test_len: 1000,
        epochs: 5,
        r: 0.5,
        a: 0.5,
        m: 3.0, // the paper's Section-3 hyper-parameters
        verbose: true,
        ..Default::default()
    };
    println!(
        "end-to-end: {} on {} ({} epochs, graph batch from manifest)",
        cfg.arch, cfg.dataset, cfg.epochs
    );
    let train = data::open(&cfg.dataset, true, cfg.train_len).map_err(anyhow::Error::msg)?;
    let test = data::open(&cfg.dataset, false, cfg.test_len).map_err(anyhow::Error::msg)?;

    let mut trainer = Trainer::new(&mut rt, &manifest, cfg.clone())?;
    println!(
        "graph {} | {} weights | batch {}",
        trainer.graph_name(),
        trainer.model.n_weights(),
        trainer.batch_size()
    );
    let report = trainer.run(train.as_ref(), test.as_ref())?;

    println!("\nloss curve    : {}", report.recorder.sparkline("loss", 72));
    println!("test-err curve: {}", report.recorder.sparkline("test_err", 24));
    println!("final test acc: {:.2}%", 100.0 * report.test_acc);
    println!(
        "per-step      : {:.0} ms ({:.0} ms graph, {:.1} ms DST)",
        report.step_time_ms, report.exec_time_ms, report.dst_time_ms
    );
    println!(
        "weight memory : {:.1} KiB packed vs {:.1} KiB fp32",
        report.packed_bytes as f64 / 1024.0,
        report.fp32_bytes as f64 / 1024.0
    );

    // checkpoint round-trip: accuracy must be bit-identical
    let path = "target/train_mnist.ckpt";
    checkpoint::save(&trainer.model, path).map_err(anyhow::Error::msg)?;
    let acc1 = trainer.evaluate(test.as_ref())?;
    let mut restored = Trainer::new(&mut rt, &manifest, cfg)?;
    checkpoint::load(&mut restored.model, path).map_err(anyhow::Error::msg)?;
    let acc2 = restored.evaluate(test.as_ref())?;
    assert_eq!(acc1, acc2, "checkpoint round-trip changed accuracy");
    println!("checkpoint    : {path} (round-trip verified, {:.2}%)", 100.0 * acc2);

    // dump the curve for EXPERIMENTS.md
    report.recorder.save_csv("target/train_mnist_curve.csv")?;
    println!("curve CSV     : target/train_mnist_curve.csv");
    Ok(())
}
