//! Fig. 13 scenario: the unified framework beyond binary/ternary.
//!
//! The discrete spaces of weights (N1) and activations (N2) are free
//! knobs: Z_0 (binary, BNN territory), Z_1 (ternary, GXNOR), up to
//! Z_6 x Z_4 — the paper's reported optimum on MNIST. This example trains
//! a small grid and prints an accuracy map plus the per-point weight
//! memory cost (bits/weight), showing the accuracy-vs-hardware trade the
//! paper's Section 3.D uses to pick a space for a given platform.
//!
//! It runs **device-free** on the native multi-bitplane engine: no
//! lowered artifacts and no PJRT client are needed (a manifest, when
//! present, only contributes shapes/batch size).
//!
//! ```sh
//! cargo run --release --example multilevel
//! ```

use gxnor::coordinator::trainer::{TrainBackend, TrainConfig};
use gxnor::runtime::exec::EngineKind;
use gxnor::runtime::manifest::Manifest;
use gxnor::sweep;
use gxnor::ternary::DiscreteSpace;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts").ok();
    if manifest.is_none() {
        println!("no artifacts/manifest.json: using catalogue shapes (fully device-free)");
    }
    let mut backend = TrainBackend::Native { manifest: manifest.as_ref() };
    let base = TrainConfig {
        train_len: 3000,
        test_len: 800,
        epochs: 3,
        engine: EngineKind::Native,
        verbose: false,
        ..Default::default()
    };
    // a diagonal + the paper's sweet spot (N1=6, N2=4)
    let grid: Vec<(u32, u32)> = vec![(1, 1), (2, 2), (3, 3), (4, 4), (6, 4)];
    println!("training the (N1, N2) grid {grid:?} (3 epochs each)…\n");
    let points = sweep::sweep_levels(&mut backend, &base, &grid)?;

    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>14}",
        "space", "test_acc", "bits/weight", "w states", "act levels"
    );
    for (p, &(n1, n2)) in points.iter().zip(&grid) {
        let ws = DiscreteSpace::new(n1);
        let as_ = DiscreteSpace::new(n2);
        println!(
            "{:<12} {:>9.2}% {:>12} {:>12} {:>14}",
            p.label,
            100.0 * p.test_acc,
            ws.bits_per_state(),
            ws.n_states(),
            as_.n_states(),
        );
    }
    if let Some(best) = sweep::best(&points) {
        println!(
            "\nbest: {} — finer spaces help up to a point (Fig. 13's interior \
             optimum), at the cost of bits/weight",
            best.label
        );
    }
    Ok(())
}
